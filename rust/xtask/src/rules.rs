//! The qadx-lint rule passes.
//!
//! Every pass works on the token stream from [`crate::lexer`] plus two
//! structural side tables computed here: bracket mate indices and
//! test-code token ranges (`#[test]` / `#[cfg(test)]` items), so findings
//! never fire on test scaffolding unless a rule opts in.
//!
//! Rules (ids are what `allow(..)` annotations name):
//! * `ordered-reduction` — closures passed to `for_chunks`/`for_chunks2`
//!   must not accumulate (`+=`, `-=`, assigned `.sum()`/`.product()`)
//!   into captured state; chunk-local and closure-local accumulation is
//!   fine. Applies everywhere, including tests.
//! * `nondet-iteration` — no `HashMap`/`HashSet` in numeric or
//!   serialization-facing modules (conservative: any non-`use` mention,
//!   so iteration can never sneak in behind an alias); `BTreeMap` or an
//!   explicit sort is the sanctioned shape, a deliberate exception
//!   carries an allow-annotation.
//! * `hot-path-panic` — no `unwrap`/`expect`/`panic!`-family (and, where
//!   configured, slice indexing) inside the serve scheduler / sampler /
//!   decode-session hot functions; degrade through `Result` instead.
//! * `unbounded-growth` — no push/insert into a scheduler/router queue
//!   field outside the functions that run its admission check; a queue
//!   that grows on a path admission never saw is the memory-leak shape
//!   of an overload bug. Deliberate exceptions (a helper whose callers
//!   all sit behind admission) carry an allow-annotation.
//! * `wall-clock` — no `Instant::now`/`SystemTime::now` inside numeric
//!   kernels (timing belongs to callers; kernels stay replayable).
//! * `artifact-keys` — cross-language key check, see [`crate::keys`].
//! * `annotation` — meta-rule: malformed / reason-less / unknown-rule /
//!   unused allow-annotations are themselves findings.

use std::collections::BTreeSet;

use crate::lexer::{lex, Kind, Lexed, Tok};

pub const RULE_ORDERED_REDUCTION: &str = "ordered-reduction";
pub const RULE_NONDET_ITERATION: &str = "nondet-iteration";
pub const RULE_HOT_PATH_PANIC: &str = "hot-path-panic";
pub const RULE_UNBOUNDED_GROWTH: &str = "unbounded-growth";
pub const RULE_WALL_CLOCK: &str = "wall-clock";
pub const RULE_ARTIFACT_KEYS: &str = "artifact-keys";
pub const RULE_ANNOTATION: &str = "annotation";

pub const KNOWN_RULES: &[&str] = &[
    RULE_ORDERED_REDUCTION,
    RULE_NONDET_ITERATION,
    RULE_HOT_PATH_PANIC,
    RULE_UNBOUNDED_GROWTH,
    RULE_WALL_CLOCK,
    RULE_ARTIFACT_KEYS,
    RULE_ANNOTATION,
];

#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: String,
    pub file: String,
    pub line: u32,
    pub msg: String,
    /// True once a valid allow-annotation covered this finding.
    pub allowed: bool,
}

impl Finding {
    fn new(rule: &str, file: &str, line: u32, msg: String) -> Finding {
        Finding { rule: rule.to_string(), file: file.to_string(), line, msg, allowed: false }
    }
}

/// Hot-path rule scope: named functions of one file.
#[derive(Debug, Clone)]
pub struct HotPathSpec {
    pub file: String,
    pub fns: Vec<String>,
    /// Also flag slice/array indexing (`x[i]`, `&x[..n]`) in those
    /// functions. Off for numeric kernels, where indexing is the idiom
    /// and bounds are structural; on for the scheduler, where an index
    /// panic kills every in-flight request.
    pub index_check: bool,
}

/// unbounded-growth rule scope: the queue-like fields of one file and the
/// functions allowed to grow them (the ones that run the admission check).
#[derive(Debug, Clone)]
pub struct GrowthSpec {
    pub file: String,
    /// Field/binding names that hold admission-bounded queues (`queue`,
    /// `lane_int`, ...). Matched on the identifier a grow call is made
    /// on, so destructured bindings of the field are covered too.
    pub fields: Vec<String>,
    /// Functions that may grow those fields: the admission-checked entry
    /// points plus internal movers that only recycle already-admitted
    /// work (requeue, dispatch put-back).
    pub admission_fns: Vec<String>,
}

/// What the linter enforces where. Paths are repo-relative with `/`
/// separators; a file is covered when its path starts with an entry.
#[derive(Debug, Clone, Default)]
pub struct Config {
    pub nondet_paths: Vec<String>,
    pub wallclock_paths: Vec<String>,
    pub hot_paths: Vec<HotPathSpec>,
    pub growth: Vec<GrowthSpec>,
}

impl Config {
    /// The repo's enforcement map (the single source of truth for which
    /// modules each rule covers — extend it as modules are added).
    pub fn repo() -> Config {
        let hot = |file: &str, fns: &[&str], index_check: bool| HotPathSpec {
            file: file.to_string(),
            fns: fns.iter().map(|s| s.to_string()).collect(),
            index_check,
        };
        let grow = |file: &str, fields: &[&str], fns: &[&str]| GrowthSpec {
            file: file.to_string(),
            fields: fields.iter().map(|s| s.to_string()).collect(),
            admission_fns: fns.iter().map(|s| s.to_string()).collect(),
        };
        Config {
            // numeric modules + everything whose output is serialized
            // (telemetry JSONL, manifest, exper reports, checkpoints)
            nondet_paths: [
                "rust/src/quant/",
                "rust/src/util/gemm.rs",
                "rust/src/util/stream.rs",
                "rust/src/eval/",
                "rust/src/runtime/refmodel.rs",
                "rust/src/runtime/reference.rs",
                "rust/src/runtime/paged.rs",
                "rust/src/runtime/engine.rs",
                "rust/src/runtime/manifest.rs",
                "rust/src/api/serve.rs",
                "rust/src/api/fleet.rs",
                "rust/src/api/session.rs",
                "rust/src/api/telemetry.rs",
                "rust/src/exper/",
                "rust/src/coordinator/",
            ]
            .iter()
            .map(|s| s.to_string())
            .collect(),
            wallclock_paths: [
                "rust/src/quant/",
                "rust/src/util/gemm.rs",
                "rust/src/util/pool.rs",
                "rust/src/runtime/refmodel.rs",
                "rust/src/runtime/paged.rs",
            ]
            .iter()
            .map(|s| s.to_string())
            .collect(),
            hot_paths: vec![
                hot(
                    "rust/src/api/serve.rs",
                    &[
                        "submit",
                        "submit_class",
                        "poll",
                        "drain",
                        "admit",
                        "step_round",
                        "dispatch",
                        "run_batch",
                        "evict_youngest_batch",
                        "emit_token",
                        "relay_streams",
                        "close_stream",
                    ],
                    true,
                ),
                hot(
                    "rust/src/api/fleet.rs",
                    &[
                        "submit",
                        "submit_class",
                        "poll",
                        "drain",
                        "dispatch",
                        "on_event",
                        "requeue",
                        "expire",
                        "admit_job",
                        "step_round",
                        "evict_youngest_batch",
                        "relay_streams",
                        "close_stream",
                    ],
                    true,
                ),
                hot("rust/src/eval/sampler.rs", &["generate", "generate_stepped"], false),
                hot(
                    "rust/src/runtime/refmodel.rs",
                    &[
                        "prefill",
                        "step",
                        "step_position",
                        "step_gemm",
                        "step_gemm_w",
                        "step_rmsnorm",
                        "step_gelu",
                    ],
                    false,
                ),
                hot("rust/src/runtime/reference.rs", &["prefill", "step"], false),
                // packed-domain GEMM tier: the per-token dot micro-kernels;
                // slice indexing is the kernel idiom here (no index_check)
                hot(
                    "rust/src/quant/packed.rs",
                    &["matvec_into", "gemm_into", "dot_row"],
                    false,
                ),
                // paged decode-state allocator: per-token hot path; slice
                // indexing is bounds-proven by construction (no index_check)
                hot(
                    "rust/src/runtime/paged.rs",
                    &["alloc", "retain", "release", "push", "row", "fork", "clear"],
                    false,
                ),
            ],
            growth: vec![
                grow(
                    "rust/src/api/serve.rs",
                    &["queue", "lane_int", "lane_bat", "pending"],
                    &["submit", "submit_class"],
                ),
                grow(
                    "rust/src/api/fleet.rs",
                    &["lane_int", "lane_bat", "streams"],
                    &["submit", "submit_class", "requeue", "dispatch"],
                ),
            ],
        }
    }
}

/// One analyzed file: findings carry `allowed` after [`finalize`].
pub struct FileAnalysis {
    pub rel: String,
    pub lexed: Lexed,
    pub findings: Vec<Finding>,
}

/// Mate index per bracket token (`(`/`)`, `[`/`]`, `{`/`}`), both ways.
fn bracket_mates(toks: &[Tok]) -> Vec<Option<usize>> {
    let mut mate = vec![None; toks.len()];
    let mut stack: Vec<(usize, char)> = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        if t.kind != Kind::Punct || t.text.len() != 1 {
            continue;
        }
        match t.text.as_bytes()[0] as char {
            c @ ('(' | '[' | '{') => stack.push((i, c)),
            c @ (')' | ']' | '}') => {
                let want = match c {
                    ')' => '(',
                    ']' => '[',
                    _ => '{',
                };
                if let Some(&(j, open)) = stack.last() {
                    if open == want {
                        stack.pop();
                        mate[i] = Some(j);
                        mate[j] = Some(i);
                    }
                }
            }
            _ => {}
        }
    }
    mate
}

/// Token-index ranges belonging to `#[test]` / `#[cfg(test)]` items.
fn test_ranges(toks: &[Tok], mate: &[Option<usize>]) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    let mut i = 0usize;
    while i + 1 < toks.len() {
        if !(toks[i].text == "#" && toks[i + 1].text == "[") {
            i += 1;
            continue;
        }
        let Some(close) = mate[i + 1] else {
            i += 1;
            continue;
        };
        let is_test = toks[i + 2..close].iter().any(|t| t.kind == Kind::Ident && t.text == "test");
        let mut k = close + 1;
        if is_test {
            // skip any further attributes on the same item
            while k + 1 < toks.len() && toks[k].text == "#" && toks[k + 1].text == "[" {
                match mate[k + 1] {
                    Some(c) => k = c + 1,
                    None => break,
                }
            }
            // brace-less items (`#[cfg(test)] use ...;`) have no range
            let mut body = None;
            let mut j = k;
            while j < toks.len() {
                if toks[j].text == ";" {
                    break;
                }
                if toks[j].text == "{" {
                    body = mate[j].map(|c| (j, c));
                    break;
                }
                j += 1;
            }
            if let Some(r) = body {
                ranges.push(r);
                i = r.1 + 1;
                continue;
            }
        }
        i = close + 1;
    }
    ranges
}

fn in_ranges(ranges: &[(usize, usize)], idx: usize) -> bool {
    ranges.iter().any(|&(a, b)| idx >= a && idx <= b)
}

/// Walk the left-hand side of an assignment ending just before `end`
/// (exclusive) back to its base identifier: `self.stats.x`, `out[i]`,
/// `*total.lock().unwrap()` all resolve to their leftmost identifier.
fn lhs_base_ident(
    toks: &[Tok],
    mate: &[Option<usize>],
    end: usize,
    floor: usize,
) -> Option<String> {
    const STOP_KEYWORDS: &[&str] = &["let", "mut", "ref", "if", "else", "match", "return", "in"];
    let mut base: Option<String> = None;
    let mut p = end;
    while p > floor {
        p -= 1;
        let t = &toks[p];
        match t.kind {
            Kind::Punct => match t.text.as_str() {
                ")" | "]" => match mate[p] {
                    Some(open) if open > floor => p = open,
                    _ => break,
                },
                "." | "::" | "*" => {}
                _ => break,
            },
            Kind::Ident => {
                if STOP_KEYWORDS.contains(&t.text.as_str()) {
                    break;
                }
                base = Some(t.text.clone());
            }
            Kind::Num => {} // tuple index like `x.0`
            _ => break,
        }
    }
    base
}

/// ordered-reduction: scan every `for_chunks`/`for_chunks2` call site.
fn ordered_reduction(rel: &str, toks: &[Tok], mate: &[Option<usize>], out: &mut Vec<Finding>) {
    let n = toks.len();
    for i in 0..n {
        if toks[i].kind != Kind::Ident
            || (toks[i].text != "for_chunks" && toks[i].text != "for_chunks2")
        {
            continue;
        }
        if i + 1 >= n || toks[i + 1].text != "(" {
            continue;
        }
        let open = i + 1;
        let Some(close) = mate[open] else { continue };
        // first `|` (or `||`) at direct argument depth opens the closure
        let mut j = open + 1;
        let mut params_end = None;
        let mut locals: BTreeSet<String> = BTreeSet::new();
        while j < close {
            let t = &toks[j];
            if t.kind == Kind::Punct && matches!(t.text.as_str(), "(" | "[" | "{") {
                j = mate[j].unwrap_or(close);
            } else if t.kind == Kind::Punct && t.text == "||" {
                params_end = Some(j);
                break;
            } else if t.kind == Kind::Punct && t.text == "|" {
                // params run to the matching `|`
                let mut k = j + 1;
                while k < close && toks[k].text != "|" {
                    if toks[k].kind == Kind::Ident {
                        locals.insert(toks[k].text.clone());
                    }
                    k += 1;
                }
                params_end = Some(k);
                break;
            }
            j += 1;
        }
        let Some(body_start) = params_end else { continue };
        let body = (body_start + 1)..close;

        // collect closure-local names: `let` bindings, `for` loop vars,
        // nested-closure params (over-approximate: every ident between a
        // `|..|` pair). Over-approximating locals can only silence, never
        // invent, a finding.
        let mut k = body.start;
        while k < body.end {
            let t = &toks[k];
            if t.kind == Kind::Ident && t.text == "let" {
                let mut m = k + 1;
                while m < body.end && toks[m].text != "=" && toks[m].text != ";" {
                    if toks[m].kind == Kind::Ident && toks[m].text != "mut" {
                        locals.insert(toks[m].text.clone());
                    }
                    m += 1;
                }
                k = m;
            } else if t.kind == Kind::Ident && t.text == "for" {
                let mut m = k + 1;
                while m < body.end && !(toks[m].kind == Kind::Ident && toks[m].text == "in") {
                    if toks[m].kind == Kind::Ident {
                        locals.insert(toks[m].text.clone());
                    }
                    m += 1;
                }
                k = m;
            } else if t.kind == Kind::Punct && t.text == "|" {
                let mut m = k + 1;
                while m < body.end && toks[m].text != "|" {
                    if toks[m].kind == Kind::Ident {
                        locals.insert(toks[m].text.clone());
                    }
                    m += 1;
                }
                k = m;
            }
            k += 1;
        }

        for k in body.clone() {
            let t = &toks[k];
            if t.kind == Kind::Punct && (t.text == "+=" || t.text == "-=") {
                let base = lhs_base_ident(toks, mate, k, body.start.saturating_sub(1));
                if let Some(b) = base {
                    if !locals.contains(&b) {
                        out.push(Finding::new(
                            RULE_ORDERED_REDUCTION,
                            rel,
                            t.line,
                            format!(
                                "`{} {}` accumulates into captured `{b}` inside a \
                                 {} closure; parallel chunk order must not feed a shared \
                                 float chain — accumulate into the chunk itself",
                                b, t.text, toks[i].text
                            ),
                        ));
                    }
                }
            }
            if t.kind == Kind::Ident
                && (t.text == "sum" || t.text == "product")
                && k > 0
                && toks[k - 1].text == "."
                && k + 1 < body.end
                && (toks[k + 1].text == "(" || toks[k + 1].text == "::")
            {
                // find the statement's assignment target, if any
                let mut s = k;
                while s > body.start && !matches!(toks[s - 1].text.as_str(), ";" | "{" | "}") {
                    s -= 1;
                }
                let mut eq = None;
                let mut m = s;
                while m < k {
                    if toks[m].kind == Kind::Punct
                        && (toks[m].text == "=" || toks[m].text == "+=")
                    {
                        eq = Some(m);
                        break;
                    }
                    if matches!(toks[m].text.as_str(), "(" | "[" | "{") {
                        m = mate[m].unwrap_or(k);
                    }
                    m += 1;
                }
                if let Some(e) = eq {
                    if let Some(b) = lhs_base_ident(toks, mate, e, s.saturating_sub(1)) {
                        if !locals.contains(&b) {
                            out.push(Finding::new(
                                RULE_ORDERED_REDUCTION,
                                rel,
                                t.line,
                                format!(
                                    "`.{}()` result assigned to captured `{b}` inside a \
                                     {} closure — reduce into the chunk instead",
                                    t.text, toks[i].text
                                ),
                            ));
                        }
                    }
                }
            }
        }
    }
}

/// nondet-iteration: HashMap/HashSet mentions in covered modules.
fn nondet_iteration(
    rel: &str,
    toks: &[Tok],
    tests: &[(usize, usize)],
    out: &mut Vec<Finding>,
) {
    for (i, t) in toks.iter().enumerate() {
        if t.kind != Kind::Ident || (t.text != "HashMap" && t.text != "HashSet") {
            continue;
        }
        if in_ranges(tests, i) {
            continue;
        }
        // skip `use` statements: the ban is on usage sites
        let mut s = i;
        while s > 0 && !matches!(toks[s - 1].text.as_str(), ";" | "{" | "}") {
            s -= 1;
        }
        if toks[s].kind == Kind::Ident && toks[s].text == "use" {
            continue;
        }
        out.push(Finding::new(
            RULE_NONDET_ITERATION,
            rel,
            t.line,
            format!(
                "`{}` in a deterministic-order module; use BTreeMap/BTreeSet or sort \
                 at the emission point",
                t.text
            ),
        ));
    }
}

/// hot-path-panic: panic family (and optionally indexing) in hot fns.
fn hot_path_panic(
    spec: &HotPathSpec,
    toks: &[Tok],
    mate: &[Option<usize>],
    tests: &[(usize, usize)],
    out: &mut Vec<Finding>,
) {
    let n = toks.len();
    let mut i = 0usize;
    while i + 1 < n {
        let is_fn = toks[i].kind == Kind::Ident
            && toks[i].text == "fn"
            && toks[i + 1].kind == Kind::Ident
            && spec.fns.iter().any(|f| *f == toks[i + 1].text)
            && !in_ranges(tests, i);
        if !is_fn {
            i += 1;
            continue;
        }
        let name = toks[i + 1].text.clone();
        // body = first top-level `{` of the item (a `;` first means a
        // trait method declaration — skip)
        let mut j = i + 2;
        let mut body = None;
        while j < n {
            if toks[j].text == ";" {
                break;
            }
            if toks[j].text == "{" {
                body = mate[j].map(|c| (j + 1, c));
                break;
            }
            if matches!(toks[j].text.as_str(), "(" | "[") {
                j = mate[j].unwrap_or(j);
            }
            j += 1;
        }
        let Some((b0, b1)) = body else {
            i += 1;
            continue;
        };
        for k in b0..b1 {
            let t = &toks[k];
            if t.kind == Kind::Ident
                && (t.text == "unwrap" || t.text == "expect")
                && k > 0
                && toks[k - 1].text == "."
                && k + 1 < n
                && toks[k + 1].text == "("
            {
                out.push(Finding::new(
                    RULE_HOT_PATH_PANIC,
                    &spec.file,
                    t.line,
                    format!(
                        "`.{}()` in hot-path fn `{name}` — a panic here kills the whole \
                         scheduler; degrade through Result",
                        t.text
                    ),
                ));
            }
            if t.kind == Kind::Ident
                && matches!(t.text.as_str(), "panic" | "unreachable" | "todo" | "unimplemented")
                && k + 1 < n
                && toks[k + 1].text == "!"
            {
                out.push(Finding::new(
                    RULE_HOT_PATH_PANIC,
                    &spec.file,
                    t.line,
                    format!("`{}!` in hot-path fn `{name}`", t.text),
                ));
            }
            if spec.index_check && t.kind == Kind::Punct && t.text == "[" && k > b0 {
                let prev = &toks[k - 1];
                let indexable = prev.kind == Kind::Ident
                    && !matches!(prev.text.as_str(), "mut" | "ref" | "return" | "in" | "as")
                    || (prev.kind == Kind::Punct && (prev.text == "]" || prev.text == ")"));
                if indexable {
                    out.push(Finding::new(
                        RULE_HOT_PATH_PANIC,
                        &spec.file,
                        t.line,
                        format!(
                            "slice/array index in hot-path fn `{name}` — use \
                             get/get_mut or iterators (an out-of-range panic kills the \
                             scheduler)"
                        ),
                    ));
                }
            }
        }
        i = b1 + 1;
    }
}

/// Every `fn` item's (name, body token range), innermost-capable: nested
/// fns get their own entry, and a token index resolves to the tightest
/// enclosing body.
fn fn_spans(toks: &[Tok], mate: &[Option<usize>]) -> Vec<(String, usize, usize)> {
    let n = toks.len();
    let mut spans = Vec::new();
    for i in 0..n.saturating_sub(1) {
        if !(toks[i].kind == Kind::Ident
            && toks[i].text == "fn"
            && toks[i + 1].kind == Kind::Ident)
        {
            continue;
        }
        // body = first top-level `{` of the item (a `;` first means a
        // trait method declaration — skip)
        let mut j = i + 2;
        while j < n {
            if toks[j].text == ";" {
                break;
            }
            if toks[j].text == "{" {
                if let Some(c) = mate[j] {
                    spans.push((toks[i + 1].text.clone(), j + 1, c));
                }
                break;
            }
            if matches!(toks[j].text.as_str(), "(" | "[") {
                j = mate[j].unwrap_or(j);
            }
            j += 1;
        }
    }
    spans
}

/// The name of the tightest fn body containing `idx`, if any.
fn enclosing_fn<'a>(spans: &'a [(String, usize, usize)], idx: usize) -> Option<&'a str> {
    spans
        .iter()
        .filter(|(_, b0, b1)| idx >= *b0 && idx <= *b1)
        .min_by_key(|(_, b0, b1)| b1 - b0)
        .map(|(name, _, _)| name.as_str())
}

/// unbounded-growth: grow calls (`push`/`push_back`/`push_front`/
/// `insert`/`entry`) on admission-bounded queue fields outside the
/// functions that run the admission check.
fn unbounded_growth(
    spec: &GrowthSpec,
    toks: &[Tok],
    mate: &[Option<usize>],
    tests: &[(usize, usize)],
    out: &mut Vec<Finding>,
) {
    const GROW_METHODS: &[&str] = &["push", "push_back", "push_front", "insert", "entry"];
    let spans = fn_spans(toks, mate);
    let n = toks.len();
    for i in 0..n.saturating_sub(3) {
        let t = &toks[i];
        if t.kind != Kind::Ident || !spec.fields.iter().any(|f| *f == t.text) {
            continue;
        }
        if !(toks[i + 1].text == "."
            && toks[i + 2].kind == Kind::Ident
            && GROW_METHODS.contains(&toks[i + 2].text.as_str())
            && toks[i + 3].text == "(")
        {
            continue;
        }
        if in_ranges(tests, i) {
            continue;
        }
        match enclosing_fn(&spans, i) {
            Some(f) if spec.admission_fns.iter().any(|a| *a == f) => continue,
            _ => {}
        }
        out.push(Finding::new(
            RULE_UNBOUNDED_GROWTH,
            &spec.file,
            toks[i + 2].line,
            format!(
                "`{}.{}(..)` grows an admission-bounded queue outside the \
                 admission-checked paths ({}); enqueue through them or annotate \
                 why this site cannot overrun",
                t.text,
                toks[i + 2].text,
                spec.admission_fns.join("/")
            ),
        ));
    }
}

/// wall-clock: Instant::now / SystemTime::now in numeric kernels.
fn wall_clock(rel: &str, toks: &[Tok], tests: &[(usize, usize)], out: &mut Vec<Finding>) {
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.kind == Kind::Ident
            && (t.text == "Instant" || t.text == "SystemTime")
            && i + 2 < toks.len()
            && toks[i + 1].text == "::"
            && toks[i + 2].text == "now"
            && !in_ranges(tests, i)
        {
            out.push(Finding::new(
                RULE_WALL_CLOCK,
                rel,
                t.line,
                format!("`{}::now` in a numeric module — timing belongs to callers", t.text),
            ));
        }
    }
}

/// Run every structural rule over one file. Annotations are applied later
/// by [`finalize`], after cross-file rules (artifact-keys) have appended
/// their findings.
pub fn analyze_source(rel: &str, src: &str, cfg: &Config) -> FileAnalysis {
    let lexed = lex(src);
    let mate = bracket_mates(&lexed.toks);
    let tests = test_ranges(&lexed.toks, &mate);
    let mut findings = Vec::new();

    ordered_reduction(rel, &lexed.toks, &mate, &mut findings);
    if cfg.nondet_paths.iter().any(|p| rel.starts_with(p.as_str())) {
        nondet_iteration(rel, &lexed.toks, &tests, &mut findings);
    }
    if cfg.wallclock_paths.iter().any(|p| rel.starts_with(p.as_str())) {
        wall_clock(rel, &lexed.toks, &tests, &mut findings);
    }
    for spec in &cfg.hot_paths {
        if spec.file == rel {
            hot_path_panic(spec, &lexed.toks, &mate, &tests, &mut findings);
        }
    }
    for spec in &cfg.growth {
        if spec.file == rel {
            unbounded_growth(spec, &lexed.toks, &mate, &tests, &mut findings);
        }
    }
    FileAnalysis { rel: rel.to_string(), lexed, findings }
}

/// Apply allow-annotations: mark covered findings `allowed`, then turn
/// annotation problems (malformed / missing reason / unknown rule /
/// unused) into findings of the `annotation` meta-rule.
pub fn finalize(fa: &mut FileAnalysis) {
    let mut ann_findings = Vec::new();
    let mut valid: Vec<(u32, Vec<String>, usize)> = Vec::new(); // (target_line, rules, ann idx)
    let mut used = vec![false; fa.lexed.annotations.len()];

    for (ai, ann) in fa.lexed.annotations.iter().enumerate() {
        if let Some(msg) = &ann.malformed {
            ann_findings.push(Finding::new(
                RULE_ANNOTATION,
                &fa.rel,
                ann.line,
                format!("malformed qadx-lint annotation: {msg}"),
            ));
            continue;
        }
        let mut ok = true;
        for r in &ann.rules {
            if !KNOWN_RULES.contains(&r.as_str()) {
                ann_findings.push(Finding::new(
                    RULE_ANNOTATION,
                    &fa.rel,
                    ann.line,
                    format!("unknown rule `{r}` in allow annotation"),
                ));
                ok = false;
            }
        }
        if !ann.has_reason {
            ann_findings.push(Finding::new(
                RULE_ANNOTATION,
                &fa.rel,
                ann.line,
                "allow annotation requires a reason: `allow(..) -- <why>`".to_string(),
            ));
            ok = false;
        }
        if !ok {
            continue;
        }
        // trailing comment covers its own line; a standalone comment
        // covers the next code line
        let target = if fa.lexed.code_lines.contains(&ann.line) {
            ann.line
        } else {
            match fa.lexed.code_lines.range(ann.line + 1..).next() {
                Some(&l) => l,
                None => continue,
            }
        };
        valid.push((target, ann.rules.clone(), ai));
    }

    for f in fa.findings.iter_mut() {
        for (target, rules, ai) in &valid {
            if f.line == *target && rules.iter().any(|r| *r == f.rule) {
                f.allowed = true;
                used[*ai] = true;
            }
        }
    }
    for (target, _, ai) in &valid {
        if !used[*ai] {
            ann_findings.push(Finding::new(
                RULE_ANNOTATION,
                &fa.rel,
                fa.lexed.annotations[*ai].line,
                format!("unused allow annotation (no matching finding on line {target})"),
            ));
        }
    }
    fa.findings.extend(ann_findings);
    fa.findings.sort_by(|a, b| (a.line, a.rule.clone()).cmp(&(b.line, b.rule.clone())));
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(rel: &str, src: &str, cfg: &Config) -> Vec<Finding> {
        let mut fa = analyze_source(rel, src, cfg);
        finalize(&mut fa);
        fa.findings
    }

    fn cfg_all(rel: &str) -> Config {
        Config {
            nondet_paths: vec![rel.to_string()],
            wallclock_paths: vec![rel.to_string()],
            hot_paths: vec![HotPathSpec {
                file: rel.to_string(),
                fns: vec!["hot".to_string()],
                index_check: true,
            }],
            growth: vec![GrowthSpec {
                file: rel.to_string(),
                fields: vec!["queue".to_string(), "lane_int".to_string()],
                admission_fns: vec!["submit".to_string()],
            }],
        }
    }

    #[test]
    fn captured_accumulation_fires_and_chunk_local_does_not() {
        let bad = "fn f(xs: &mut [f32]) { let mut total = 0f32; \
                   pool::for_chunks(n, xs, c, |i, chunk| { for v in chunk.iter() { total += v; } }); }";
        let f = run("m.rs", bad, &cfg_all("m.rs"));
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, RULE_ORDERED_REDUCTION);
        let ok = "fn f(xs: &mut [f32]) { \
                  pool::for_chunks(n, xs, c, |i, chunk| { let mut acc = 0f32; \
                  for v in 0..chunk.len() { acc += 1.0; chunk[v] += acc; } }); }";
        assert!(run("m.rs", ok, &cfg_all("m.rs")).is_empty());
    }

    #[test]
    fn assigned_sum_into_captured_state_fires() {
        let bad = "fn f() { pool::for_chunks2(w, a, 1, b, 1, |i, ca, cb| { \
                   self.total = ca.iter().sum::<f32>(); }); }";
        let f = run("m.rs", bad, &cfg_all("m.rs"));
        assert_eq!(f.len(), 1, "{f:?}");
        let ok = "fn f() { pool::for_chunks2(w, a, 1, b, 1, |i, ca, cb| { \
                  let s: f32 = ca.iter().sum(); cb[0] = s; }); }";
        assert!(run("m.rs", ok, &cfg_all("m.rs")).is_empty());
    }

    #[test]
    fn hashmap_fires_only_in_covered_modules_and_not_on_use_lines() {
        let src = "use std::collections::HashMap;\nstruct S { m: HashMap<u32, u32> }\n";
        let f = run("rust/src/api/serve.rs", src, &Config::repo());
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].line, 2);
        assert!(run("rust/src/data/loader.rs", src, &Config::repo()).is_empty());
    }

    #[test]
    fn cfg_test_items_are_exempt_from_module_rules() {
        let src = "#[cfg(test)]\nmod tests {\n  use std::collections::HashMap;\n  fn t() { let m: HashMap<u32,u32> = HashMap::new(); let _ = Instant::now(); }\n}\n";
        assert!(run("m.rs", src, &cfg_all("m.rs")).is_empty());
    }

    #[test]
    fn hot_path_panics_and_indexing_fire_by_function() {
        let src = "impl S {\n fn hot(&mut self) { let x = self.q.pop().unwrap(); self.rows[x] = 1; }\n fn cold(&mut self) { self.q.pop().unwrap(); }\n}";
        let f = run("m.rs", src, &cfg_all("m.rs"));
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f.iter().all(|x| x.rule == RULE_HOT_PATH_PANIC));
        assert!(f.iter().any(|x| x.msg.contains("unwrap")));
        assert!(f.iter().any(|x| x.msg.contains("index")));
    }

    #[test]
    fn unbounded_growth_fires_outside_admission_fns_only() {
        let bad = "impl S {\n fn submit(&mut self) { self.queue.push_back(1); }\n \
                   fn refill(&mut self) { self.queue.push_back(2); lane_int.push_front(3); }\n}";
        let f = run("m.rs", bad, &cfg_all("m.rs"));
        let un: Vec<_> = f.iter().filter(|x| !x.allowed).collect();
        assert_eq!(un.len(), 2, "{un:?}");
        assert!(un.iter().all(|x| x.rule == RULE_UNBOUNDED_GROWTH), "{un:?}");
        assert!(un.iter().all(|x| x.line == 3), "both sites are in refill: {un:?}");
        assert!(un[0].msg.contains("submit"), "names the admission fns: {un:?}");
    }

    #[test]
    fn unbounded_growth_spares_other_fields_tests_and_allows() {
        // non-queue fields and non-grow methods never fire
        let ok = "impl S {\n fn refill(&mut self) { self.out.push(1); self.queue.pop_front(); } }";
        assert!(run("m.rs", ok, &cfg_all("m.rs")).is_empty());
        // test scaffolding is exempt
        let test = "#[test]\nfn t() { queue.push_back(1); }";
        assert!(run("m.rs", test, &cfg_all("m.rs")).is_empty());
        // a reasoned allow-annotation keeps the gate green but reports
        let allowed = "impl S {\n fn helper(&mut self) {\n  \
                       // qadx-lint: allow(unbounded-growth) -- callers gate on submit\n  \
                       self.queue.push_back(1);\n }\n}";
        let f = run("m.rs", allowed, &cfg_all("m.rs"));
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].allowed, "{f:?}");
    }

    #[test]
    fn unbounded_growth_resolves_nested_fns_to_the_innermost_body() {
        // a nested helper inside an admission fn is NOT itself admission
        let src = "impl S {\n fn submit(&mut self) {\n  fn inner(q: &mut Q) { \
                   q.lane_int.push_back(1); }\n  self.queue.push_back(2);\n }\n}";
        let f = run("m.rs", src, &cfg_all("m.rs"));
        let un: Vec<_> = f.iter().filter(|x| !x.allowed).collect();
        assert_eq!(un.len(), 1, "{un:?}");
        assert!(un[0].msg.contains("lane_int"), "{un:?}");
    }

    #[test]
    fn wall_clock_fires_in_numeric_modules() {
        let src = "fn f() { let t = Instant::now(); }";
        let f = run("m.rs", src, &cfg_all("m.rs"));
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, RULE_WALL_CLOCK);
    }

    #[test]
    fn allow_annotation_suppresses_and_unused_or_reasonless_is_flagged() {
        let ok = "struct S {\n  // qadx-lint: allow(nondet-iteration) -- never iterated\n  m: HashMap<u32, u32>,\n}";
        let f = run("m.rs", ok, &cfg_all("m.rs"));
        assert_eq!(f.len(), 1);
        assert!(f[0].allowed, "{f:?}");
        let no_reason = "struct S {\n  // qadx-lint: allow(nondet-iteration)\n  m: HashMap<u32, u32>,\n}";
        let f = run("m.rs", no_reason, &cfg_all("m.rs"));
        assert!(f.iter().any(|x| x.rule == RULE_ANNOTATION && !x.allowed));
        assert!(f.iter().any(|x| x.rule == RULE_NONDET_ITERATION && !x.allowed));
        let unused = "// qadx-lint: allow(wall-clock) -- nothing here\nfn f() {}\n";
        let f = run("m.rs", unused, &cfg_all("m.rs"));
        assert_eq!(f.len(), 1);
        assert!(f[0].msg.contains("unused"), "{f:?}");
    }

    #[test]
    fn trailing_annotation_covers_its_own_line() {
        let src =
            "struct S { m: HashMap<u32, u32> } // qadx-lint: allow(nondet-iteration) -- ok here";
        let f = run("m.rs", src, &cfg_all("m.rs"));
        assert_eq!(f.len(), 1);
        assert!(f[0].allowed);
    }

    #[test]
    fn unknown_rule_in_annotation_is_a_finding() {
        let src = "// qadx-lint: allow(made-up-rule) -- why\nfn f() {}\n";
        let f = run("m.rs", src, &cfg_all("m.rs"));
        assert_eq!(f.len(), 1);
        assert!(f[0].msg.contains("unknown rule"));
    }
}
