//! Cross-language artifact-key check.
//!
//! The python lowering side (`python/compile/aot.py`, `steps.py`) and the
//! Rust runtime must agree on artifact key names (`fwd_bf16`,
//! `qad_nvfp4`, `fwd_last_*` frontier keys, `scalars`, ...). A key that
//! exists on only one side is a latent runtime error: python emits an
//! artifact nobody loads, or Rust requests one the lowering never wrote.
//!
//! Key literals are recognized by shape: `scalars`, or `<family>_<rest>`
//! for the step/forward families. Format interpolations (`f"fwd_{fmt}"`,
//! `format!("fwd_last_{rest}")`) become `*` wildcards and match any
//! concrete key of their family; literals ending in `_` are prefix
//! probes (e.g. `strip_prefix("fwd_")`), not keys.

use crate::lexer::{Kind, Lexed};
use crate::rules::{Finding, RULE_ARTIFACT_KEYS};

const FAMILIES: &[&str] = &["fwd_", "sft_", "qat_", "qad_", "mse_", "nqt_", "rl_"];

/// A key literal occurrence.
#[derive(Debug, Clone)]
pub struct KeyUse {
    pub key: String,
    pub file: String,
    pub line: u32,
}

/// Normalize a string literal to a key pattern, or None when the literal
/// is not key-shaped.
pub fn key_pattern(lit: &str) -> Option<String> {
    // interpolations ({fmt}, {rest}, {}) become wildcards
    let mut out = String::new();
    let mut depth = 0usize;
    for c in lit.chars() {
        match c {
            '{' => {
                depth += 1;
                if depth == 1 {
                    out.push('*');
                }
            }
            '}' => depth = depth.saturating_sub(1),
            _ if depth == 0 => out.push(c),
            _ => {}
        }
    }
    if out == "scalars" {
        return Some(out);
    }
    if !FAMILIES.iter().any(|f| out.starts_with(f)) {
        return None;
    }
    if out.ends_with('_') {
        return None; // prefix probe, not a key
    }
    if !out.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_' || c == '*') {
        return None;
    }
    Some(out)
}

/// `pattern` ⊇ `key`? Simple `*`-wildcard match (greedy segment scan).
pub fn wildcard_match(pattern: &str, key: &str) -> bool {
    if !pattern.contains('*') {
        return pattern == key;
    }
    let segs: Vec<&str> = pattern.split('*').collect();
    let mut rest = key;
    for (i, seg) in segs.iter().enumerate() {
        if i == 0 {
            match rest.strip_prefix(seg) {
                Some(r) => rest = r,
                None => return false,
            }
        } else if i == segs.len() - 1 {
            return seg.is_empty() || rest.ends_with(seg);
        } else if let Some(at) = rest.find(seg) {
            rest = &rest[at + seg.len()..];
        } else {
            return false;
        }
    }
    true
}

/// Harvest key-shaped string literals from a lexed Rust file.
pub fn rust_keys(rel: &str, lexed: &Lexed) -> Vec<KeyUse> {
    let mut out = Vec::new();
    for t in &lexed.toks {
        if t.kind != Kind::Str {
            continue;
        }
        if let Some(k) = key_pattern(&t.text) {
            out.push(KeyUse { key: k, file: rel.to_string(), line: t.line });
        }
    }
    out
}

/// Harvest key-shaped string literals from python source (handles `'`/`"`
/// strings, triple quotes, `#` comments; f-string interpolations become
/// wildcards via [`key_pattern`]).
pub fn python_keys(rel: &str, src: &str) -> Vec<KeyUse> {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == '#' {
            while i < n && chars[i] != '\n' {
                i += 1;
            }
            continue;
        }
        if c == '"' || c == '\'' {
            let q = c;
            let triple = i + 2 < n && chars[i + 1] == q && chars[i + 2] == q;
            let start_line = line;
            let mut text = String::new();
            if triple {
                i += 3;
                while i < n {
                    if chars[i] == q && i + 2 < n && chars[i + 1] == q && chars[i + 2] == q {
                        i += 3;
                        break;
                    }
                    if chars[i] == '\n' {
                        line += 1;
                    }
                    text.push(chars[i]);
                    i += 1;
                }
            } else {
                i += 1;
                while i < n && chars[i] != q && chars[i] != '\n' {
                    if chars[i] == '\\' && i + 1 < n {
                        text.push(chars[i]);
                        text.push(chars[i + 1]);
                        i += 2;
                        continue;
                    }
                    text.push(chars[i]);
                    i += 1;
                }
                if i < n && chars[i] == q {
                    i += 1;
                }
            }
            if let Some(k) = key_pattern(&text) {
                out.push(KeyUse { key: k, file: rel.to_string(), line: start_line });
            }
            continue;
        }
        i += 1;
    }
    out
}

/// Lines of python source carrying `# qadx-lint: allow(artifact-keys) --`
/// (the python side's minimal annotation channel); a finding on line L is
/// allowed when L or L-1 carries one.
fn python_allow_lines(src: &str) -> Vec<u32> {
    let mut out = Vec::new();
    for (ln, text) in src.lines().enumerate() {
        if let Some(at) = text.find('#') {
            let c = &text[at..];
            if c.contains("qadx-lint:") && c.contains("allow(artifact-keys)") && c.contains("--") {
                out.push(ln as u32 + 1);
            }
        }
    }
    out
}

/// Cross-check: every concrete key on one side must be matched (exactly
/// or by a wildcard pattern) on the other. Returns (rust-side findings,
/// python-side findings) — rust-side ones flow through the standard
/// annotation engine; python-side ones are pre-filtered here.
pub fn cross_check(
    rust: &[KeyUse],
    python: &[KeyUse],
    python_srcs: &[(String, String)],
) -> (Vec<Finding>, Vec<Finding>) {
    let covered = |k: &str, other: &[KeyUse]| other.iter().any(|o| wildcard_match(&o.key, k));
    let mut seen = std::collections::BTreeSet::new();
    let mut rust_out = Vec::new();
    for u in rust {
        if u.key.contains('*') || !seen.insert(u.key.clone()) {
            continue;
        }
        if !covered(&u.key, python) {
            rust_out.push(Finding {
                rule: RULE_ARTIFACT_KEYS.to_string(),
                file: u.file.clone(),
                line: u.line,
                msg: format!(
                    "artifact key \"{}\" is used by Rust but never lowered by \
                     python/compile — one-sided keys fail at runtime",
                    u.key
                ),
                allowed: false,
            });
        }
    }
    let mut seen_py = std::collections::BTreeSet::new();
    let mut py_out = Vec::new();
    for u in python {
        if u.key.contains('*') || !seen_py.insert(u.key.clone()) {
            continue;
        }
        if !covered(&u.key, rust) {
            let allow = python_srcs
                .iter()
                .find(|(f, _)| *f == u.file)
                .map(|(_, src)| python_allow_lines(src))
                .unwrap_or_default();
            let allowed = allow.iter().any(|&l| l == u.line || l + 1 == u.line);
            py_out.push(Finding {
                rule: RULE_ARTIFACT_KEYS.to_string(),
                file: u.file.clone(),
                line: u.line,
                msg: format!(
                    "artifact key \"{}\" is lowered by python/compile but never \
                     referenced from the Rust runtime",
                    u.key
                ),
                allowed,
            });
        }
    }
    (rust_out, py_out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    #[test]
    fn key_pattern_filters_shapes() {
        assert_eq!(key_pattern("fwd_bf16"), Some("fwd_bf16".to_string()));
        assert_eq!(key_pattern("qad_nvfp4_xsuper"), Some("qad_nvfp4_xsuper".to_string()));
        assert_eq!(key_pattern("scalars"), Some("scalars".to_string()));
        assert_eq!(key_pattern("fwd_last_{rest}"), Some("fwd_last_*".to_string()));
        assert_eq!(key_pattern("fwd_"), None, "prefix probe");
        assert_eq!(key_pattern("qad"), None, "method name, not a key");
        assert_eq!(key_pattern("forward pass"), None);
        assert_eq!(key_pattern("fwd_BF16"), None, "keys are lowercase");
    }

    #[test]
    fn wildcard_match_families() {
        assert!(wildcard_match("fwd_*", "fwd_bf16"));
        assert!(wildcard_match("fwd_last_*", "fwd_last_nvfp4"));
        assert!(!wildcard_match("fwd_last_*", "fwd_bf16"));
        assert!(wildcard_match("fwd_bf16", "fwd_bf16"));
        assert!(!wildcard_match("fwd_bf16", "fwd_nvfp4"));
    }

    #[test]
    fn cross_check_flags_one_sided_keys_both_ways() {
        let rs = lex("fn f() { load(\"fwd_bf16\"); load(\"qat_only_in_rust\"); }");
        let rust = rust_keys("rust/src/x.rs", &rs);
        let py_src = "KEYS = [\"fwd_bf16\", \"mse_only_in_python\"]\n".to_string();
        let python = python_keys("python/compile/aot.py", &py_src);
        let srcs = vec![("python/compile/aot.py".to_string(), py_src)];
        let (r, p) = cross_check(&rust, &python, &srcs);
        assert_eq!(r.len(), 1, "{r:?}");
        assert!(r[0].msg.contains("qat_only_in_rust"));
        assert_eq!(p.len(), 1, "{p:?}");
        assert!(p[0].msg.contains("mse_only_in_python"));
    }

    #[test]
    fn wildcards_cover_concrete_keys_across_sides() {
        let rs = lex("fn f() { let k = format!(\"fwd_last_{rest}\"); }");
        let rust = rust_keys("rust/src/x.rs", &rs);
        let py_src = "emit(f\"fwd_last_{fmt}\")\nemit(\"fwd_last_bf16\")\n".to_string();
        let python = python_keys("python/compile/aot.py", &py_src);
        let srcs = vec![("python/compile/aot.py".to_string(), py_src)];
        let (r, p) = cross_check(&rust, &python, &srcs);
        assert!(r.is_empty(), "{r:?}");
        // python's concrete fwd_last_bf16 is covered by rust's wildcard
        assert!(p.is_empty(), "{p:?}");
    }

    #[test]
    fn python_allow_annotation_suppresses() {
        let py_src = "# qadx-lint: allow(artifact-keys) -- lowered for external tools\nemit(\"nqt_external\")\n"
            .to_string();
        let python = python_keys("python/compile/aot.py", &py_src);
        let srcs = vec![("python/compile/aot.py".to_string(), py_src)];
        let (_, p) = cross_check(&[], &python, &srcs);
        assert_eq!(p.len(), 1);
        assert!(p[0].allowed, "{p:?}");
    }
}
