//! Minimal Rust lexer for qadx-lint.
//!
//! Produces a flat token stream with line numbers plus the side channels
//! the rule passes need: `// qadx-lint: allow(..)` annotations harvested
//! from comments, and the set of lines that carry real code (used to bind
//! a standalone annotation comment to the next code line). The lexer
//! understands just enough real Rust — nested block comments, string /
//! raw-string / byte-string literals, char literals vs lifetimes — that
//! rule passes never mistake literal or comment text for code.

use std::collections::BTreeSet;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Num,
    Str,
    Char,
    Lifetime,
    Punct,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: u32,
}

/// One `// qadx-lint: allow(rule[, rule]) -- reason` comment.
#[derive(Debug, Clone)]
pub struct Annotation {
    pub line: u32,
    pub rules: Vec<String>,
    pub has_reason: bool,
    /// Set when the comment names qadx-lint but does not parse.
    pub malformed: Option<String>,
}

#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub annotations: Vec<Annotation>,
    /// Lines on which at least one token appears.
    pub code_lines: BTreeSet<u32>,
}

/// Multi-char punctuation, longest first so maximal munch wins.
const PUNCTS: &[&str] = &[
    "..=", "<<=", ">>=", "+=", "-=", "*=", "/=", "%=", "^=", "&=", "|=", "::", "->", "=>", "==",
    "!=", "<=", ">=", "&&", "||", "<<", ">>", "..",
];

pub const ANNOTATION_TAG: &str = "qadx-lint:";

/// Parse one comment body (text after `//`) as an allow-annotation.
/// Returns None when the comment does not mention qadx-lint at all.
pub fn parse_annotation(line: u32, comment: &str) -> Option<Annotation> {
    let at = comment.find(ANNOTATION_TAG)?;
    let rest = comment[at + ANNOTATION_TAG.len()..].trim();
    let malformed = |msg: &str| {
        Some(Annotation {
            line,
            rules: vec![],
            has_reason: false,
            malformed: Some(msg.to_string()),
        })
    };
    let Some(body) = rest.strip_prefix("allow(") else {
        return malformed("expected `allow(<rule>[, <rule>]) -- <reason>`");
    };
    let Some(close) = body.find(')') else {
        return malformed("unclosed `allow(`");
    };
    let mut rules = Vec::new();
    for part in body[..close].split(',') {
        let r = part.trim();
        if r.is_empty() {
            return malformed("empty rule name in allow(..)");
        }
        rules.push(r.to_string());
    }
    let tail = body[close + 1..].trim();
    let has_reason = match tail.strip_prefix("--") {
        Some(reason) => !reason.trim().is_empty(),
        None => false,
    };
    Some(Annotation { line, rules, has_reason, malformed: None })
}

pub fn lex(src: &str) -> Lexed {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;

    let is_ident_start = |c: char| c.is_alphabetic() || c == '_';
    let is_ident_cont = |c: char| c.is_alphanumeric() || c == '_';

    fn push(toks: &mut Vec<Tok>, lines: &mut BTreeSet<u32>, kind: Kind, text: String, ln: u32) {
        lines.insert(ln);
        toks.push(Tok { kind, text, line: ln });
    }

    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // line comment (and the annotation channel)
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i + 2;
            let mut j = start;
            while j < n && chars[j] != '\n' {
                j += 1;
            }
            let text: String = chars[start..j].iter().collect();
            if let Some(ann) = parse_annotation(line, &text) {
                out.annotations.push(ann);
            }
            i = j;
            continue;
        }
        // nested block comment
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if chars[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if chars[j] == '/' && j + 1 < n && chars[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if chars[j] == '*' && j + 1 < n && chars[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            i = j;
            continue;
        }
        // identifier, or a string prefix (r"", b"", br"", b'')
        if is_ident_start(c) {
            let start = i;
            let mut j = i;
            while j < n && is_ident_cont(chars[j]) {
                j += 1;
            }
            let word: String = chars[start..j].iter().collect();
            // raw / byte string prefixes
            if (word == "r" || word == "b" || word == "br" || word == "rb")
                && j < n
                && (chars[j] == '"' || chars[j] == '#')
                && word != "b"
            {
                // raw string: r"..." / r#"..."# (any # count)
                let mut hashes = 0usize;
                let mut k = j;
                while k < n && chars[k] == '#' {
                    hashes += 1;
                    k += 1;
                }
                if k < n && chars[k] == '"' {
                    k += 1;
                    let body_start = k;
                    let ln = line;
                    'raw: while k < n {
                        if chars[k] == '\n' {
                            line += 1;
                            k += 1;
                            continue;
                        }
                        if chars[k] == '"' {
                            let mut h = 0usize;
                            while k + 1 + h < n && h < hashes && chars[k + 1 + h] == '#' {
                                h += 1;
                            }
                            if h == hashes {
                                let text: String = chars[body_start..k].iter().collect();
                                push(&mut out.toks, &mut out.code_lines, Kind::Str, text, ln);
                                k += 1 + hashes;
                                break 'raw;
                            }
                        }
                        k += 1;
                    }
                    i = k;
                    continue;
                }
                // not actually a raw string (e.g. `r#ident`): fall through
            }
            if word == "b" && j < n && (chars[j] == '"' || chars[j] == '\'') {
                // byte string / byte char: lex as the underlying literal
                i = j;
                continue; // next loop iteration handles the quote
            }
            push(&mut out.toks, &mut out.code_lines, Kind::Ident, word, line);
            i = j;
            continue;
        }
        // number (loose: digits, `_`, suffixes, one decimal part; stops
        // before `..` so ranges survive)
        if c.is_ascii_digit() {
            let start = i;
            let mut j = i;
            while j < n && (is_ident_cont(chars[j])) {
                j += 1;
            }
            if j < n && chars[j] == '.' && !(j + 1 < n && chars[j + 1] == '.') {
                // decimal part (also tolerates `1.` and `1.0f32`)
                j += 1;
                while j < n && is_ident_cont(chars[j]) {
                    j += 1;
                }
            }
            let text: String = chars[start..j].iter().collect();
            push(&mut out.toks, &mut out.code_lines, Kind::Num, text, line);
            i = j;
            continue;
        }
        // cooked string
        if c == '"' {
            let ln = line;
            let mut j = i + 1;
            let mut text = String::new();
            while j < n {
                match chars[j] {
                    '\\' if j + 1 < n => {
                        // keep escapes opaque; they never form key text
                        text.push(chars[j]);
                        text.push(chars[j + 1]);
                        if chars[j + 1] == '\n' {
                            line += 1;
                        }
                        j += 2;
                    }
                    '"' => {
                        j += 1;
                        break;
                    }
                    '\n' => {
                        line += 1;
                        text.push('\n');
                        j += 1;
                    }
                    ch => {
                        text.push(ch);
                        j += 1;
                    }
                }
            }
            push(&mut out.toks, &mut out.code_lines, Kind::Str, text, ln);
            i = j;
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            if i + 1 < n && chars[i + 1] == '\\' {
                // escaped char literal: '\n', '\'', '\\', '\u{..}' — the
                // char right after the backslash is part of the escape
                // (crucial for '\''), so the closing-quote scan starts
                // one past it
                let mut j = i + 3;
                while j < n && chars[j] != '\'' {
                    j += 1;
                }
                push(&mut out.toks, &mut out.code_lines, Kind::Char, String::new(), line);
                i = (j + 1).min(n);
                continue;
            }
            if i + 1 < n && is_ident_start(chars[i + 1]) {
                let mut j = i + 1;
                while j < n && is_ident_cont(chars[j]) {
                    j += 1;
                }
                if j < n && chars[j] == '\'' && j == i + 2 {
                    // 'a' — single-char literal
                    push(&mut out.toks, &mut out.code_lines, Kind::Char, String::new(), line);
                    i = j + 1;
                } else {
                    let text: String = chars[i + 1..j].iter().collect();
                    push(&mut out.toks, &mut out.code_lines, Kind::Lifetime, text, line);
                    i = j;
                }
                continue;
            }
            // '0', '+', non-ascii, ...
            let mut j = i + 1;
            while j < n && chars[j] != '\'' && chars[j] != '\n' {
                j += 1;
            }
            push(&mut out.toks, &mut out.code_lines, Kind::Char, String::new(), line);
            i = (j + 1).min(n);
            continue;
        }
        // punctuation (maximal munch)
        let mut matched = false;
        for p in PUNCTS {
            let pc: Vec<char> = p.chars().collect();
            if i + pc.len() <= n && chars[i..i + pc.len()] == pc[..] {
                push(&mut out.toks, &mut out.code_lines, Kind::Punct, p.to_string(), line);
                i += pc.len();
                matched = true;
                break;
            }
        }
        if !matched {
            push(&mut out.toks, &mut out.code_lines, Kind::Punct, c.to_string(), line);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).toks.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn strings_and_comments_do_not_leak_tokens() {
        let l = lex("let x = \"HashMap // not a comment\"; // HashMap\n/* unwrap() */ y");
        let idents: Vec<&str> = l
            .toks
            .iter()
            .filter(|t| t.kind == Kind::Ident)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(idents, vec!["let", "x", "y"]);
        let strs: Vec<&str> = l
            .toks
            .iter()
            .filter(|t| t.kind == Kind::Str)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(strs, vec!["HashMap // not a comment"]);
    }

    #[test]
    fn raw_strings_and_nested_block_comments() {
        let l = lex("r#\"unwrap() \"quoted\" \"# /* a /* nested */ still */ z");
        assert_eq!(l.toks.len(), 2);
        assert_eq!(l.toks[0].kind, Kind::Str);
        assert_eq!(l.toks[0].text, "unwrap() \"quoted\" ");
        assert_eq!(l.toks[1].text, "z");
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let l = lex("fn f<'a>(x: &'a str) { let c = 'x'; let nl = '\\n'; }");
        let lifetimes: Vec<&str> = l
            .toks
            .iter()
            .filter(|t| t.kind == Kind::Lifetime)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(lifetimes, vec!["a", "a"]);
        assert_eq!(l.toks.iter().filter(|t| t.kind == Kind::Char).count(), 2);
    }

    #[test]
    fn escaped_quote_char_literal_does_not_swallow_code() {
        // '\'' once terminated the scan at the ESCAPED quote, leaving a
        // stray ' that ate everything to the next quote/newline —
        // silently hiding real tokens from every rule pass
        let l = lex("if c == '\\'' || c == '\\\\' { HashMap } else { '\\u{7f}'; }");
        let idents: Vec<&str> = l
            .toks
            .iter()
            .filter(|t| t.kind == Kind::Ident)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(idents, vec!["if", "c", "c", "HashMap", "else"]);
        assert_eq!(l.toks.iter().filter(|t| t.kind == Kind::Char).count(), 3);
    }

    #[test]
    fn compound_punct_and_ranges() {
        assert_eq!(texts("a += 1; b[..n] 0..=3"), vec![
            "a", "+=", "1", ";", "b", "[", "..", "n", "]", "0", "..=", "3"
        ]);
    }

    #[test]
    fn line_numbers_track_every_literal_form() {
        let src = "a\n\"two\nlines\"\n/* c\nc */ b\nc";
        let l = lex(src);
        let by_text: Vec<(String, u32)> =
            l.toks.iter().map(|t| (t.text.clone(), t.line)).collect();
        assert_eq!(by_text, vec![
            ("a".to_string(), 1),
            ("two\nlines".to_string(), 2),
            ("b".to_string(), 5),
            ("c".to_string(), 6),
        ]);
    }

    #[test]
    fn annotation_parses_rules_and_reason() {
        let l = lex("// qadx-lint: allow(nondet-iteration, hot-path-panic) -- cache never iterates\nlet x = 1;");
        assert_eq!(l.annotations.len(), 1);
        let a = &l.annotations[0];
        assert_eq!(a.rules, vec!["nondet-iteration", "hot-path-panic"]);
        assert!(a.has_reason);
        assert!(a.malformed.is_none());
        assert_eq!(a.line, 1);
    }

    #[test]
    fn annotation_without_reason_or_malformed_is_recorded() {
        let l = lex("// qadx-lint: allow(wall-clock)\n// qadx-lint: deny(everything)\n");
        assert_eq!(l.annotations.len(), 2);
        assert!(!l.annotations[0].has_reason);
        assert!(l.annotations[0].malformed.is_none());
        assert!(l.annotations[1].malformed.is_some());
    }
}
