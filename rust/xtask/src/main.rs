//! qadx-lint — repo-native determinism & numerics static analysis.
//!
//! Usage:
//!   cargo run -p xtask -- lint [--json] [--root <repo-root>]
//!
//! Scans rust/src, rust/tests, rust/benches, examples/ plus the python
//! lowering side (python/compile/{aot,steps}.py) and enforces the rules
//! documented in rust/xtask/README.md. Exit status: 0 when every finding
//! is covered by an allow-annotation, 1 on any unallowed finding, 2 on
//! usage/IO errors.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use xtask::rules::{Config, Finding};
use xtask::run_lint;

fn json_escape(s: &str) -> String {
    let mut out = String::new();
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut json = false;
    let mut root: Option<PathBuf> = None;
    let mut cmd = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "lint" if cmd.is_none() => cmd = Some("lint"),
            "--json" => json = true,
            "--root" => match it.next() {
                Some(r) => root = Some(PathBuf::from(r)),
                None => {
                    eprintln!("--root requires a path");
                    return ExitCode::from(2);
                }
            },
            other => {
                eprintln!("unknown argument {other:?}");
                eprintln!("usage: cargo run -p xtask -- lint [--json] [--root <repo-root>]");
                return ExitCode::from(2);
            }
        }
    }
    if cmd != Some("lint") {
        eprintln!("usage: cargo run -p xtask -- lint [--json] [--root <repo-root>]");
        return ExitCode::from(2);
    }
    // default root: this crate lives at <root>/rust/xtask
    let root =
        root.unwrap_or_else(|| Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(".."));

    let findings = match run_lint(&root, &Config::repo()) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("qadx-lint: io error: {e}");
            return ExitCode::from(2);
        }
    };
    let unallowed: Vec<&Finding> = findings.iter().filter(|f| !f.allowed).collect();
    let allowed = findings.len() - unallowed.len();

    if json {
        let mut out = String::from("{\"findings\":[");
        for (i, f) in findings.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"rule\":\"{}\",\"file\":\"{}\",\"line\":{},\"allowed\":{},\"message\":\"{}\"}}",
                json_escape(&f.rule),
                json_escape(&f.file),
                f.line,
                f.allowed,
                json_escape(&f.msg)
            ));
        }
        out.push_str(&format!("],\"allowed\":{},\"unallowed\":{}}}", allowed, unallowed.len()));
        println!("{out}");
    } else {
        for f in &unallowed {
            println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.msg);
        }
        println!(
            "qadx-lint: {} finding(s) ({} allowed by annotation, {} unallowed)",
            findings.len(),
            allowed,
            unallowed.len()
        );
    }
    if unallowed.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
