//! Step-vs-full decode equivalence: `Sampler::generate` must emit
//! bit-identical token rows whether it runs the stateful prefill+step
//! path (`DecodeMode::Step`) or the stateless full-forward path
//! (`DecodeMode::Full`) — across block stacks (attn-only, ssm, hybrid
//! attn+ssm+moe), precisions (bf16/nvfp4), sampling regimes (greedy and
//! top-p), EOS finishing rows mid-batch, prompt lengths straddling
//! seq_len, and thread counts.
//!
//! Entirely hermetic: reference backend over synthetic manifests. CI pins
//! `QADX_THREADS=2` on this suite so the parallel compute core is what
//! the stateless side exercises; the 1-vs-4 thread test pins both counts
//! explicitly on top.

mod common;

use qadx::coordinator::init_params;
use qadx::data::tokenizer as tok;
use qadx::eval::{DecodeMode, SampleCfg, Sampler};
use qadx::runtime::{ModelRuntime, SynthSpec};
use qadx::util::pool;

fn spec_with_blocks(name: &str, blocks: &[&str]) -> SynthSpec {
    let mut spec = common::small_spec(name);
    spec.blocks = blocks.iter().map(|s| s.to_string()).collect();
    spec.n_experts = if blocks.contains(&"moe") { 3 } else { 0 };
    spec
}

/// Decode the same prompts under Step and Full modes and assert the
/// emitted rows are identical (same tokens, same EOS/PAD structure).
fn assert_step_matches_full(
    tag: &str,
    blocks: &[&str],
    fwd_key: &str,
    cfg: SampleCfg,
    prompts: &[Vec<i32>],
) -> Vec<Vec<i32>> {
    let engine = common::reference_engine(tag, &[spec_with_blocks("eq-sim", blocks)]);
    let rt = ModelRuntime::new(&engine, "eq-sim").unwrap();
    let params = init_params(&rt.model, 41);
    let p_buf = rt.upload_params(&params).unwrap();

    let mut stepped = Sampler::new(&rt, fwd_key, cfg).unwrap();
    stepped.set_decode_mode(DecodeMode::Step);
    let mut full = Sampler::new(&rt, fwd_key, cfg).unwrap();
    full.set_decode_mode(DecodeMode::Full);

    let a = stepped.generate(&engine, &p_buf, prompts, None).unwrap();
    let b = full.generate(&engine, &p_buf, prompts, None).unwrap();
    assert_eq!(a, b, "step vs full diverged ({blocks:?}, {fwd_key}, {cfg:?})");
    common::cleanup(tag);
    a
}

fn varied_prompts(n: usize) -> Vec<Vec<i32>> {
    (0..n)
        .map(|i| {
            let mut p = vec![tok::BOS];
            p.extend((0..=i).map(|j| 4 + ((i * 5 + j) % 8) as i32));
            p
        })
        .collect()
}

#[test]
fn step_matches_full_attn_only() {
    let prompts = varied_prompts(3);
    for fwd_key in ["fwd_bf16", "fwd_nvfp4"] {
        assert_step_matches_full(
            "deq_attn",
            &["attn", "attn"],
            fwd_key,
            SampleCfg { temperature: 0.8, top_p: 0.9, max_new: 8, seed: 11 },
            &prompts,
        );
        assert_step_matches_full(
            "deq_attn_g",
            &["attn", "attn"],
            fwd_key,
            SampleCfg::greedy(),
            &prompts,
        );
    }
}

#[test]
fn step_matches_full_ssm() {
    let prompts = varied_prompts(2);
    for fwd_key in ["fwd_bf16", "fwd_nvfp4"] {
        assert_step_matches_full(
            "deq_ssm",
            &["ssm", "ssm"],
            fwd_key,
            SampleCfg { temperature: 0.7, top_p: 0.95, max_new: 8, seed: 13 },
            &prompts,
        );
    }
}

#[test]
fn step_matches_full_hybrid() {
    let prompts = varied_prompts(4);
    for (tag, cfg) in [
        ("deq_hyb_tp", SampleCfg { temperature: 1.0, top_p: 0.85, max_new: 10, seed: 17 }),
        ("deq_hyb_g", SampleCfg::greedy()),
    ] {
        for fwd_key in ["fwd_bf16", "fwd_nvfp4"] {
            assert_step_matches_full(tag, &["attn", "ssm", "moe"], fwd_key, cfg, &prompts);
        }
    }
}

#[test]
fn step_matches_full_state_weights_key() {
    // fwd_bf16_state binds the packed train state as the weights buffer
    let engine = common::reference_engine("deq_state", &[spec_with_blocks("eq-sim", &["attn"])]);
    let rt = ModelRuntime::new(&engine, "eq-sim").unwrap();
    let params = init_params(&rt.model, 43);
    let mut state = vec![0f32; rt.model.state_len];
    state[..rt.model.param_count].copy_from_slice(&params);
    let s_buf = engine.upload_f32(&state, &[rt.model.state_len]).unwrap();
    let cfg = SampleCfg { temperature: 0.6, top_p: 0.95, max_new: 6, seed: 19 };
    let prompts = varied_prompts(2);
    let mut stepped = Sampler::new(&rt, "fwd_bf16_state", cfg).unwrap();
    stepped.set_decode_mode(DecodeMode::Step);
    let mut full = Sampler::new(&rt, "fwd_bf16_state", cfg).unwrap();
    full.set_decode_mode(DecodeMode::Full);
    let a = stepped.generate(&engine, &s_buf, &prompts, None).unwrap();
    let b = full.generate(&engine, &s_buf, &prompts, None).unwrap();
    assert_eq!(a, b, "state-key decode diverged");
    common::cleanup("deq_state");
}

#[test]
fn prompt_lengths_straddling_seq_len() {
    // prompts at s-1 (one slot left) and past s (must truncate to s-1 and
    // still emit exactly one token), mixed with a short prompt
    let engine =
        common::reference_engine("deq_straddle", &[spec_with_blocks("eq-sim", &["attn", "ssm"])]);
    let rt = ModelRuntime::new(&engine, "eq-sim").unwrap();
    let s = rt.model.seq_len;
    let params = init_params(&rt.model, 47);
    let p_buf = rt.upload_params(&params).unwrap();
    let prompts = vec![
        vec![5i32; s - 1],     // exactly one position left
        vec![6i32; s + 3],     // longer than the row: truncated to s-1
        vec![tok::BOS, 7, 8],  // plenty of room
    ];
    let cfg = SampleCfg { temperature: 0.9, top_p: 0.9, max_new: 6, seed: 23 };
    let mut stepped = Sampler::new(&rt, "fwd_nvfp4", cfg).unwrap();
    stepped.set_decode_mode(DecodeMode::Step);
    let mut full = Sampler::new(&rt, "fwd_nvfp4", cfg).unwrap();
    full.set_decode_mode(DecodeMode::Full);
    let a = stepped.generate(&engine, &p_buf, &prompts, None).unwrap();
    let b = full.generate(&engine, &p_buf, &prompts, None).unwrap();
    assert_eq!(a, b, "straddling prompts diverged");
    for row in &a {
        assert_eq!(row.len(), s);
    }
    // the (truncated) prompts survive verbatim; only position s-1 was free
    assert_eq!(&a[0][..s - 1], &vec![5i32; s - 1][..]);
    assert_eq!(&a[1][..s - 1], &vec![6i32; s - 1][..]);
    common::cleanup("deq_straddle");
}

/// A deterministic "clock" model: no blocks, zero embeddings, one-hot
/// positional rows, identity head — the greedy token emitted at position
/// p is a pure function of p (a filler token below position K, EOS at and
/// after). Rows with different prompt lengths therefore hit EOS in
/// different decode rounds, exercising EOS-mid-batch deterministically.
fn clock_spec() -> SynthSpec {
    let mut spec = common::small_spec("clock-sim");
    spec.blocks = vec![];
    spec.n_experts = 0;
    spec.d_model = 16;
    spec.vocab = 16;
    spec.seq_len = 12;
    spec.batch = 4;
    spec
}

/// K = 6: positions 0..5 point at token 5, positions >= 5 point at EOS.
fn clock_params(spec: &SynthSpec) -> Vec<f32> {
    let entry = spec.entry();
    let (d, v, s) = (entry.d_model, entry.vocab, entry.seq_len);
    assert_eq!(d, v, "clock model needs an identity head");
    let mut params = vec![0f32; entry.param_count];
    for def in &entry.params {
        let slice = &mut params[def.offset..def.offset + def.size];
        match def.name.as_str() {
            "pos_emb" => {
                for t in 0..s {
                    let g = if t >= 5 { tok::EOS as usize } else { 5 };
                    slice[t * d + g] = 1.0;
                }
            }
            "ln_f" => slice.fill(1.0),
            "head" => {
                for j in 0..d {
                    slice[j * v + j] = 1.0;
                }
            }
            _ => {} // embed stays zero: emitted tokens never feed back
        }
    }
    params
}

#[test]
fn eos_mid_batch_rows_finish_independently_and_identically() {
    let spec = clock_spec();
    let params = clock_params(&spec);
    let engine = common::reference_engine("deq_clock", &[spec]);
    let rt = ModelRuntime::new(&engine, "clock-sim").unwrap();
    let p_buf = rt.upload_params(&params).unwrap();
    // prompt lengths 2 and 4: the long prompt reaches position K first,
    // so it EOSes at round 3 while the short row keeps generating to
    // round 5 — EOS mid-batch, deterministic under greedy decode.
    let prompts = vec![vec![1i32, 4], vec![1i32, 4, 4, 4]];
    let cfg = SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 8, seed: 0 };
    let mut stepped = Sampler::new(&rt, "fwd_bf16", cfg).unwrap();
    stepped.set_decode_mode(DecodeMode::Step);
    let mut full = Sampler::new(&rt, "fwd_bf16", cfg).unwrap();
    full.set_decode_mode(DecodeMode::Full);
    let a = stepped.generate(&engine, &p_buf, &prompts, None).unwrap();
    let b = full.generate(&engine, &p_buf, &prompts, None).unwrap();
    assert_eq!(a, b, "clock decode diverged");
    // row 0 (len 2): fillers at positions 2..=5, EOS at 6 -> 5 generated
    let mut want0 = vec![tok::PAD; 12];
    want0[..2].copy_from_slice(&[1, 4]);
    want0[2..6].fill(5);
    want0[6] = tok::EOS;
    assert_eq!(a[0], want0);
    // row 1 (len 4): fillers at 4..=5, EOS at 6 -> 3 generated (finished
    // two rounds before row 0 — mid-batch EOS by construction)
    let mut want1 = vec![tok::PAD; 12];
    want1[..4].copy_from_slice(&[1, 4, 4, 4]);
    want1[4] = 5;
    want1[5] = 5;
    want1[6] = tok::EOS;
    assert_eq!(a[1], want1);
    common::cleanup("deq_clock");
}

#[test]
fn stepped_decode_bit_identical_across_thread_counts() {
    // the stateful path at 1 and 4 workers must emit the same rows (the
    // decode-state compute runs on the shared parallel core)
    let run = |tag: &str, threads: usize| {
        pool::with_threads(threads, || {
            let mut spec = spec_with_blocks("thr-eq", &["attn", "ssm", "moe"]);
            spec.d_model = 64;
            spec.n_heads = 4;
            spec.d_ff = 128;
            spec.vocab = 256;
            spec.seq_len = 16;
            spec.n_experts = 2;
            let engine = common::reference_engine(tag, &[spec]);
            let rt = ModelRuntime::new(&engine, "thr-eq").unwrap();
            let params = init_params(&rt.model, 53);
            let p_buf = rt.upload_params(&params).unwrap();
            let cfg = SampleCfg { temperature: 0.8, top_p: 0.9, max_new: 8, seed: 29 };
            let mut s = Sampler::new(&rt, "fwd_nvfp4", cfg).unwrap();
            s.set_decode_mode(DecodeMode::Step);
            let prompts: Vec<Vec<i32>> =
                (0..rt.model.batch).map(|i| vec![4 + i as i32, 9, 6]).collect();
            s.generate(&engine, &p_buf, &prompts, None).unwrap()
        })
    };
    let one = run("deq_thr1", 1);
    let four = run("deq_thr4", 4);
    assert_eq!(one, four, "stepped decode rows diverged across thread counts");
    common::cleanup("deq_thr1");
    common::cleanup("deq_thr4");
}

#[test]
fn engine_capability_probe() {
    let engine = common::reference_engine("deq_probe", &[common::small_spec("probe-sim")]);
    let rt = ModelRuntime::new(&engine, "probe-sim").unwrap();
    let params = init_params(&rt.model, 59);
    let p_buf = rt.upload_params(&params).unwrap();
    // plain fwd key: capability present, requested slot count honored
    let sess = engine.open_decode(&rt.model, "fwd_nvfp4", &p_buf, 2).unwrap();
    let mut sess = sess.expect("reference backend has stateful decode");
    assert_eq!(sess.rows(), 2);
    assert_eq!(sess.capacity(), rt.model.seq_len);
    let mut logits = Vec::new();
    sess.prefill(1, &[1, 5, 7], &mut logits).unwrap();
    assert_eq!(logits.len(), rt.model.vocab);
    assert_eq!(sess.len(1), 3);
    assert_eq!(sess.len(0), 0);
    sess.step(1, 4, &mut logits).unwrap();
    assert_eq!(sess.len(1), 4);
    // the frontier-gather twin is stateless: probe says None, not error
    assert!(engine.open_decode(&rt.model, "fwd_last_nvfp4", &p_buf, 1).unwrap().is_none());
    common::cleanup("deq_probe");
}
