//! Paged decode-state bit-identity and accounting.
//!
//! The paged layout (fixed-size K/V pages from a refcounted pool,
//! optional shared-prefix cache with copy-on-write) must be a pure
//! storage change: every prefill/step logits row is bit-identical to the
//! dense per-slot layout across block stacks, precisions, page sizes,
//! prompt lengths straddling page boundaries, and pool thread counts —
//! and the allocator must account every page (close releases, eviction
//! frees, budgets bound memory by live tokens, a budget miss degrades
//! one request without wedging the session).
//!
//! Entirely hermetic: reference backend over synthetic manifests.

mod common;

use std::cell::RefCell;
use std::rc::Rc;

use qadx::api::{ServeCfg, ServeWeights, TokenEvent, TokenSink};
use qadx::coordinator::init_params;
use qadx::data::tokenizer as tok;
use qadx::runtime::{Buffer, DecodeOpts, DecodeSession, Engine, ModelRuntime, SynthSpec};
use qadx::util::pool;

fn spec_with_blocks(name: &str, blocks: &[&str]) -> SynthSpec {
    let mut spec = common::small_spec(name);
    spec.blocks = blocks.iter().map(|s| s.to_string()).collect();
    spec.n_experts = if blocks.contains(&"moe") { 3 } else { 0 };
    spec
}

fn open_session(
    engine: &Engine,
    rt: &ModelRuntime,
    p_buf: &Buffer,
    fwd_key: &str,
    rows: usize,
    opts: &DecodeOpts,
) -> Box<dyn DecodeSession> {
    engine
        .open_decode_opts(&rt.model, fwd_key, p_buf, rows, opts)
        .unwrap()
        .expect("reference backend has stateful decode")
}

/// Deterministic non-EOS token feed (independent of logits, so the two
/// sessions always see identical inputs).
fn feed_token(row: usize, i: usize) -> i32 {
    3 + ((row * 7 + i * 5) % 11) as i32
}

/// Drive one paged and one dense session through identical prefill+step
/// sequences and assert every logits row is bit-identical; then close
/// all rows and assert the pool drops to zero live pages.
fn assert_paged_matches_dense(
    tag: &str,
    blocks: &[&str],
    fwd_key: &str,
    page_size: usize,
    prompts: &[Vec<i32>],
    steps: usize,
) {
    let engine = common::reference_engine(tag, &[spec_with_blocks("paged-sim", blocks)]);
    let rt = ModelRuntime::new(&engine, "paged-sim").unwrap();
    let params = init_params(&rt.model, 53);
    let p_buf = rt.upload_params(&params).unwrap();
    let rows = prompts.len();
    let mut dense =
        open_session(&engine, &rt, &p_buf, fwd_key, rows, &DecodeOpts::default());
    let opts = DecodeOpts { page_size, prefix_cache: 0, max_pages: 0, kernel: None };
    let mut paged = open_session(&engine, &rt, &p_buf, fwd_key, rows, &opts);
    assert!(dense.paged_stats().is_none(), "dense sessions report no paged stats");

    let (mut ld, mut lp) = (Vec::new(), Vec::new());
    for (r, prompt) in prompts.iter().enumerate() {
        dense.prefill(r, prompt, &mut ld).unwrap();
        paged.prefill(r, prompt, &mut lp).unwrap();
        assert_eq!(
            ld, lp,
            "prefill diverged (row {r}, psz {page_size}, {fwd_key}, {blocks:?})"
        );
        for i in 0..steps.min(rt.model.seq_len - prompt.len()) {
            let t = feed_token(r, i);
            dense.step(r, t, &mut ld).unwrap();
            paged.step(r, t, &mut lp).unwrap();
            assert_eq!(
                ld, lp,
                "step {i} diverged (row {r}, psz {page_size}, {fwd_key}, {blocks:?})"
            );
        }
    }
    for r in 0..rows {
        paged.close(r).unwrap();
        dense.close(r).unwrap();
    }
    let st = paged.paged_stats().expect("paged session reports stats");
    assert_eq!(st.page_size, page_size);
    assert_eq!(st.live_pages, 0, "closed rows must release every page");
    common::cleanup(tag);
}

/// Prompt lengths straddling the 16-position page boundary (and, for
/// page size 1, every boundary): 1, psz-1, psz, psz+1.
fn straddling_prompts() -> Vec<Vec<i32>> {
    [1usize, 15, 16, 17]
        .iter()
        .map(|&n| (0..n).map(|j| 2 + (j % 9) as i32).collect())
        .collect()
}

#[test]
fn paged_matches_dense_attn_only() {
    let prompts = straddling_prompts();
    for fwd_key in ["fwd_bf16", "fwd_nvfp4"] {
        for psz in [1usize, 16, 64] {
            assert_paged_matches_dense("pgd_attn", &["attn", "attn"], fwd_key, psz, &prompts, 8);
        }
    }
}

#[test]
fn paged_matches_dense_ssm_only() {
    // SSM carries never touch the page pool, but the paged session must
    // still be bit-identical (and report zero live pages throughout).
    let prompts = straddling_prompts();
    for fwd_key in ["fwd_bf16", "fwd_nvfp4"] {
        for psz in [1usize, 16, 64] {
            assert_paged_matches_dense("pgd_ssm", &["ssm", "ssm"], fwd_key, psz, &prompts, 6);
        }
    }
}

#[test]
fn paged_matches_dense_hybrid() {
    let prompts = straddling_prompts();
    for fwd_key in ["fwd_bf16", "fwd_nvfp4"] {
        for psz in [1usize, 16, 64] {
            assert_paged_matches_dense(
                "pgd_hyb",
                &["attn", "ssm", "moe"],
                fwd_key,
                psz,
                &prompts,
                6,
            );
        }
    }
}

#[test]
fn paged_matches_dense_across_thread_counts() {
    // The step path is single-row, but prefill runs the full parallel
    // forward: the paged harvest must be thread-count invariant too.
    let prompts = straddling_prompts();
    for threads in [1usize, 4] {
        pool::with_threads(threads, || {
            let tag = format!("pgd_thr{threads}");
            assert_paged_matches_dense(
                &tag,
                &["attn", "ssm", "moe"],
                "fwd_nvfp4",
                16,
                &prompts,
                6,
            );
        });
    }
}

#[test]
fn prefix_cache_hit_prefill_is_bit_identical_to_cold() {
    let engine = common::reference_engine(
        "pgd_prefix",
        &[spec_with_blocks("paged-sim", &["attn", "ssm", "moe"])],
    );
    let rt = ModelRuntime::new(&engine, "paged-sim").unwrap();
    let params = init_params(&rt.model, 59);
    let p_buf = rt.upload_params(&params).unwrap();
    let mut dense =
        open_session(&engine, &rt, &p_buf, "fwd_nvfp4", 3, &DecodeOpts::default());
    let opts = DecodeOpts { page_size: 16, prefix_cache: 4, max_pages: 0, kernel: None };
    let mut cached = open_session(&engine, &rt, &p_buf, "fwd_nvfp4", 3, &opts);

    // 20 tokens: the shared prefix itself straddles the page boundary.
    let prompt_a: Vec<i32> = (0..20).map(|j| 2 + (j % 9) as i32).collect();
    let mut ext = prompt_a.clone();
    ext.extend_from_slice(&[7, 9]);

    let (mut ld, mut lc) = (Vec::new(), Vec::new());
    dense.prefill(0, &prompt_a, &mut ld).unwrap();
    cached.prefill(0, &prompt_a, &mut lc).unwrap();
    assert_eq!(ld, lc, "cold prefill must match dense");
    let st = cached.paged_stats().unwrap();
    assert_eq!((st.prefix_hits, st.prefix_misses), (0, 1));
    assert_eq!(st.prefix_entries, 1);

    // Exact hit: answered from the stored logits, still bit-identical.
    dense.prefill(1, &prompt_a, &mut ld).unwrap();
    cached.prefill(1, &prompt_a, &mut lc).unwrap();
    assert_eq!(ld, lc, "exact prefix hit must match cold prefill");
    let st = cached.paged_stats().unwrap();
    assert_eq!((st.prefix_hits, st.prefix_misses), (1, 1));

    // Partial hit: fork the cached pages, replay only the 2-token suffix.
    dense.prefill(2, &ext, &mut ld).unwrap();
    cached.prefill(2, &ext, &mut lc).unwrap();
    assert_eq!(ld, lc, "partial prefix hit must match cold prefill");
    let st = cached.paged_stats().unwrap();
    assert_eq!((st.prefix_hits, st.prefix_misses), (2, 1));
    assert_eq!(st.prefix_entries, 2, "the extended prompt is cached too");

    // Decode continues bit-identically on every row (COW protects the
    // cache entries when the shared partial page is appended to).
    for r in 0..3 {
        for i in 0..4 {
            let t = feed_token(r, i);
            dense.step(r, t, &mut ld).unwrap();
            cached.step(r, t, &mut lc).unwrap();
            assert_eq!(ld, lc, "post-hit step {i} diverged on row {r}");
        }
    }
    assert!(
        cached.paged_stats().unwrap().cow_copies >= 1,
        "appending into a cache-shared page must copy-on-write"
    );
    common::cleanup("pgd_prefix");
}

#[test]
fn cow_divergence_one_token_after_shared_prefix() {
    let engine =
        common::reference_engine("pgd_cow", &[spec_with_blocks("paged-sim", &["attn", "attn"])]);
    let rt = ModelRuntime::new(&engine, "paged-sim").unwrap();
    let params = init_params(&rt.model, 61);
    let p_buf = rt.upload_params(&params).unwrap();
    let mut dense =
        open_session(&engine, &rt, &p_buf, "fwd_bf16", 3, &DecodeOpts::default());
    let opts = DecodeOpts { page_size: 8, prefix_cache: 2, max_pages: 0, kernel: None };
    let mut cached = open_session(&engine, &rt, &p_buf, "fwd_bf16", 3, &opts);

    // 12 tokens -> pages [0..8) and [8..12): the second page is partial,
    // so the first post-fork append lands in shared storage.
    let prompt: Vec<i32> = (0..12).map(|j| 1 + (j % 7) as i32).collect();
    let (mut ld, mut lc) = (Vec::new(), Vec::new());
    dense.prefill(0, &prompt, &mut ld).unwrap();
    cached.prefill(0, &prompt, &mut lc).unwrap();
    assert_eq!(ld, lc);
    dense.prefill(1, &prompt, &mut ld).unwrap();
    cached.prefill(1, &prompt, &mut lc).unwrap();
    assert_eq!(ld, lc);

    // Diverge exactly one token after the shared prefix: row 0 takes 4,
    // row 1 takes 9, then both continue with identical suffixes.
    for (row, first) in [(0usize, 4i32), (1, 9)] {
        dense.step(row, first, &mut ld).unwrap();
        cached.step(row, first, &mut lc).unwrap();
        assert_eq!(ld, lc, "divergence token diverged on row {row}");
        for t in [5i32, 6, 7] {
            dense.step(row, t, &mut ld).unwrap();
            cached.step(row, t, &mut lc).unwrap();
            assert_eq!(ld, lc, "post-divergence step diverged on row {row}");
        }
    }
    let st = cached.paged_stats().unwrap();
    assert!(st.cow_copies >= 2, "both rows shared the partial page: {st:?}");

    // The donor cache entry must be untouched by either row's writes: a
    // third request replaying the prompt still matches a cold prefill.
    dense.prefill(2, &prompt, &mut ld).unwrap();
    cached.prefill(2, &prompt, &mut lc).unwrap();
    assert_eq!(ld, lc, "COW must leave the cached prefix pages intact");
    common::cleanup("pgd_cow");
}

#[test]
fn prefix_eviction_returns_pages_and_reuses_freed_slabs() {
    // 2 attention blocks x (K, V) = 4 sequences per row; 6-token prompts
    // at page size 4 take 2 pages per sequence -> 8 pages per prefill.
    let engine =
        common::reference_engine("pgd_evict", &[spec_with_blocks("paged-sim", &["attn", "attn"])]);
    let rt = ModelRuntime::new(&engine, "paged-sim").unwrap();
    let params = init_params(&rt.model, 67);
    let p_buf = rt.upload_params(&params).unwrap();
    let opts = DecodeOpts { page_size: 4, prefix_cache: 2, max_pages: 0, kernel: None };
    let mut session = open_session(&engine, &rt, &p_buf, "fwd_bf16", 1, &opts);

    let mut logits = Vec::new();
    for k in 0..3i32 {
        let prompt: Vec<i32> = (0..6).map(|j| 1 + k + (j % 3)).collect();
        session.prefill(0, &prompt, &mut logits).unwrap();
        session.close(0).unwrap();
    }
    // Three distinct prompts through a 2-entry cache: the oldest entry
    // was evicted, its 8 pages refcounted down to zero and freed.
    let st = session.paged_stats().unwrap();
    assert_eq!(st.prefix_entries, 2, "cache capacity holds: {st:?}");
    assert_eq!(st.live_pages, 16, "2 cached prefixes x 8 pages: {st:?}");
    assert_eq!(st.free_pages, 8, "the evicted entry's pages are free: {st:?}");
    let slab = st.live_pages + st.free_pages;

    // A fourth prefill must reuse the freed pages instead of growing the
    // slab (and its insert evicts the next LRU entry).
    let prompt: Vec<i32> = (0..6).map(|j| 9 + (j % 3)).collect();
    session.prefill(0, &prompt, &mut logits).unwrap();
    session.close(0).unwrap();
    let st = session.paged_stats().unwrap();
    assert_eq!(st.prefix_entries, 2);
    assert_eq!(
        st.live_pages + st.free_pages,
        slab,
        "freed pages must be recycled, not leaked alongside fresh allocations: {st:?}"
    );
    common::cleanup("pgd_evict");
}

#[test]
fn page_budget_bounds_state_by_live_tokens_and_degrades_cleanly() {
    // Dense state for 8 rows would pin 8 rows x 4 sequences x 8 pages =
    // 256 page-equivalents up front. A 40-page budget still serves all 8
    // short requests because paged memory tracks live tokens, and a
    // request that would blow the budget fails cleanly without wedging
    // the session.
    let engine =
        common::reference_engine("pgd_budget", &[spec_with_blocks("paged-sim", &["attn", "attn"])]);
    let rt = ModelRuntime::new(&engine, "paged-sim").unwrap();
    let params = init_params(&rt.model, 71);
    let p_buf = rt.upload_params(&params).unwrap();
    let rows = 8usize;
    let mut dense =
        open_session(&engine, &rt, &p_buf, "fwd_bf16", rows, &DecodeOpts::default());
    let opts = DecodeOpts { page_size: 4, prefix_cache: 0, max_pages: 40, kernel: None };
    let mut paged = open_session(&engine, &rt, &p_buf, "fwd_bf16", rows, &opts);

    let (mut ld, mut lp) = (Vec::new(), Vec::new());
    for r in 0..rows {
        let prompt = vec![1i32, 2 + r as i32];
        dense.prefill(r, &prompt, &mut ld).unwrap();
        paged.prefill(r, &prompt, &mut lp).unwrap();
        assert_eq!(ld, lp, "budget-bound prefill diverged on row {r}");
        for i in 0..2 {
            let t = feed_token(r, i);
            dense.step(r, t, &mut ld).unwrap();
            paged.step(r, t, &mut lp).unwrap();
            assert_eq!(ld, lp, "budget-bound step diverged on row {r}");
        }
    }
    let st = paged.paged_stats().unwrap();
    assert_eq!(st.live_pages, 32, "4 live tokens/row -> 1 page/sequence: {st:?}");

    // A full-length prompt needs 36 fresh pages; only 12 are left.
    let long: Vec<i32> = (0..rt.model.seq_len).map(|j| 1 + (j % 5) as i32).collect();
    let err = paged.prefill(0, &long, &mut lp).unwrap_err();
    assert!(
        err.to_string().contains("page budget exhausted"),
        "budget miss must be a clean typed failure: {err:#}"
    );

    // The session stays usable: the failed row re-prefills a short
    // prompt, still bit-identical to dense.
    dense.prefill(0, &[9, 9], &mut ld).unwrap();
    paged.prefill(0, &[9, 9], &mut lp).unwrap();
    assert_eq!(ld, lp, "session must survive a budget miss");
    common::cleanup("pgd_budget");
}

/// Build a continuous server over the given spec/params with `cfg_fn`
/// applied, run `prompts` through it, and return (sorted rows, handle).
fn serve_rows(
    tag: &str,
    name: &str,
    params: &[f32],
    cfg_fn: impl FnOnce(&mut ServeCfg),
    prompts: &[Vec<i32>],
) -> (Vec<(u64, Vec<i32>)>, qadx::api::ServeStats) {
    let session = qadx::api::Session::builder()
        .artifacts_dir(&common::write_artifacts(tag, &[spec_with_blocks(name, &["attn", "attn"])]))
        .runs_dir(common::tmp_runs(tag))
        .backend(qadx::runtime::BackendKind::Reference)
        .build()
        .unwrap();
    let ms = session.model(name).unwrap();
    let mut cfg = ServeCfg::default();
    cfg.sample = qadx::eval::SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 6, seed: 0 };
    cfg.weights = ServeWeights::Params(params.to_vec());
    cfg_fn(&mut cfg);
    let mut server = ms.server("fwd_bf16", &cfg).unwrap();
    assert!(server.continuous());
    for p in prompts {
        server.submit(p.clone()).unwrap();
    }
    let mut responses = server.drain().unwrap();
    responses.sort_by_key(|r| r.id);
    for r in &responses {
        assert!(r.error.is_none(), "id {}: {:?}", r.id, r.error);
    }
    let rows = responses.into_iter().map(|r| (r.id, r.row)).collect();
    let stats = server.stats().clone();
    drop(server);
    common::cleanup(tag);
    (rows, stats)
}

#[test]
fn serve_paged_prefix_rows_are_bit_identical_to_dense_serving() {
    // End-to-end: the same greedy request mix (with repeated and
    // prefix-extended prompts) through a dense server and a paged +
    // prefix-cached server must produce byte-identical rows.
    let spec = spec_with_blocks("paged-srv", &["attn", "attn"]);
    let params = init_params(&spec.entry(), 73);
    let base: Vec<i32> = vec![1, 4, 4, 5, 5, 4];
    let mut ext = base.clone();
    ext.extend_from_slice(&[6, 7]);
    let prompts =
        vec![base.clone(), base.clone(), ext, vec![2, 9, 9], base.clone()];

    let (dense_rows, dense_stats) =
        serve_rows("pgd_srv_dense", "paged-srv", &params, |_| {}, &prompts);
    assert_eq!(dense_stats.page_size, 0, "dense serving reports no paged gauges");

    let (paged_rows, paged_stats) = serve_rows(
        "pgd_srv_paged",
        "paged-srv",
        &params,
        |cfg| {
            cfg.page_size = 8;
            cfg.prefix_cache = 4;
        },
        &prompts,
    );
    assert_eq!(dense_rows, paged_rows, "paged+prefix serving changed a row");
    assert_eq!(paged_stats.page_size, 8);
    assert!(
        paged_stats.prefix_hits >= 2,
        "repeated/extended prompts must hit the cache: hits {} misses {}",
        paged_stats.prefix_hits,
        paged_stats.prefix_misses
    );
    let s = paged_stats.summary();
    assert!(s.contains("pages"), "summary must surface paged gauges: {s}");
    assert!(s.contains("prefix"), "{s}");
}

#[test]
fn serve_drain_releases_every_page_without_a_prefix_cache() {
    // Finished slots close their rows: with no cache holding prefixes,
    // a drained server must be back to zero live pages (no leak).
    let spec = spec_with_blocks("paged-srv", &["attn", "attn"]);
    let params = init_params(&spec.entry(), 79);
    let prompts = vec![vec![1, 4, 4, 5], vec![2, 9, 9], vec![1, 4]];
    let (_rows, stats) = serve_rows(
        "pgd_srv_drain",
        "paged-srv",
        &params,
        |cfg| cfg.page_size = 8,
        &prompts,
    );
    assert_eq!(stats.page_size, 8);
    assert_eq!(
        stats.live_pages, 0,
        "drained server must hold no pages: {}",
        stats.summary()
    );
}

#[test]
fn serve_streams_tokens_in_order_with_contiguous_indices() {
    // Clock model: prompt length L generates exactly 7 - L tokens, so
    // the streamed (id, index, token) sequences are known in advance and
    // must reconstruct each response row's generated suffix.
    let (spec, params) = common::clock_spec_and_params("clock-stream");
    let session = qadx::api::Session::builder()
        .artifacts_dir(&common::write_artifacts("pgd_stream", &[spec]))
        .runs_dir(common::tmp_runs("pgd_stream"))
        .backend(qadx::runtime::BackendKind::Reference)
        .build()
        .unwrap();
    let ms = session.model("clock-stream").unwrap();
    let events: Rc<RefCell<Vec<TokenEvent>>> = Rc::new(RefCell::new(Vec::new()));
    let sink_events = events.clone();
    let tel = common::tmp_runs("pgd_stream").join("stream.jsonl");
    let mut cfg = ServeCfg::default();
    cfg.sample = qadx::eval::SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 8, seed: 0 };
    cfg.weights = ServeWeights::Params(params);
    cfg.stream = true;
    cfg.telemetry = Some(tel.clone());
    cfg.on_token = Some(TokenSink::new(move |ev| sink_events.borrow_mut().push(*ev)));
    let mut server = ms.server("fwd_bf16", &cfg).unwrap();

    let a = server.submit(vec![1, 4, 4, 4]).unwrap(); // 3 tokens: 5, 5, EOS
    let b = server.submit(vec![1, 4]).unwrap(); //        5 tokens
    let responses = server.drain().unwrap();
    assert_eq!(responses.len(), 2);
    drop(server);

    let events = events.borrow();
    for r in &responses {
        let seq: Vec<&TokenEvent> = events.iter().filter(|e| e.id == r.id).collect();
        assert_eq!(seq.len(), r.gen_tokens, "one event per generated token (id {})", r.id);
        let plen = r.row.iter().take_while(|&&t| t != tok::PAD).count() - r.gen_tokens;
        for (i, ev) in seq.iter().enumerate() {
            assert_eq!(ev.index, i, "indices count from 0 in emission order");
            assert_eq!(ev.token, r.row[plen + i], "streamed token != row token (id {})", r.id);
            assert_eq!((ev.worker, ev.attempt), (0, 0));
        }
    }
    let by_a: Vec<i32> = events.iter().filter(|e| e.id == a).map(|e| e.token).collect();
    assert_eq!(by_a, vec![5, 5, tok::EOS]);
    let by_b: Vec<i32> = events.iter().filter(|e| e.id == b).map(|e| e.token).collect();
    assert_eq!(by_b, vec![5, 5, 5, 5, tok::EOS]);

    // cfg.stream also lands one JSONL "token" event per generated token.
    let log = std::fs::read_to_string(&tel).unwrap();
    let token_lines = log.lines().filter(|l| l.contains("\"event\":\"token\"")).count();
    assert_eq!(token_lines, events.len(), "{log}");
    common::cleanup("pgd_stream");
}

#[test]
fn serve_seq_len_boundary_prompts_resolve_without_panicking() {
    // Clock model seq_len = 12. Length 11 (seq_len - 1) is the last
    // admissible prompt: exactly one generated token (EOS — position 11
    // is past the clock's EOS point). Lengths 12 and 13 leave no room to
    // generate and must resolve as degraded responses, never panic or
    // silently truncate-and-generate.
    let (spec, params) = common::clock_spec_and_params("clock-edge");
    let session = qadx::api::Session::builder()
        .artifacts_dir(&common::write_artifacts("pgd_edge", &[spec]))
        .runs_dir(common::tmp_runs("pgd_edge"))
        .backend(qadx::runtime::BackendKind::Reference)
        .build()
        .unwrap();
    let ms = session.model("clock-edge").unwrap();
    let mut cfg = ServeCfg::default();
    cfg.sample = qadx::eval::SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 8, seed: 0 };
    cfg.weights = ServeWeights::Params(params);
    let mut server = ms.server("fwd_bf16", &cfg).unwrap();

    assert!(server.submit(vec![]).is_err(), "empty prompts are a caller error");

    let fit = server.submit(vec![1; 11]).unwrap();
    let exact = server.submit(vec![2; 12]).unwrap();
    let over = server.submit(vec![3; 13]).unwrap();
    let responses = server.drain().unwrap();
    assert_eq!(responses.len(), 3, "every submission resolves");
    let by_id = |id: u64| responses.iter().find(|r| r.id == id).unwrap();

    let r = by_id(fit);
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(r.gen_tokens, 1, "one position left -> exactly one token");
    assert_eq!(r.row[11], tok::EOS);

    for (id, plen) in [(exact, 12usize), (over, 13)] {
        let r = by_id(id);
        let err = r.error.as_deref().unwrap_or("");
        assert!(err.contains("leaves no room to generate"), "id {id}: {err:?}");
        assert!(err.contains(&plen.to_string()), "error names the length: {err:?}");
        assert_eq!(r.gen_tokens, 0, "degraded requests generate nothing");
        assert_eq!(r.row.len(), 12, "row stays seq_len-shaped");
    }
    common::cleanup("pgd_edge");
}

#[test]
fn decode_opts_reject_prefix_cache_without_pages() {
    let engine =
        common::reference_engine("pgd_opts", &[spec_with_blocks("paged-sim", &["attn"])]);
    let rt = ModelRuntime::new(&engine, "paged-sim").unwrap();
    let params = init_params(&rt.model, 83);
    let p_buf = rt.upload_params(&params).unwrap();
    let opts = DecodeOpts { page_size: 0, prefix_cache: 2, max_pages: 0, kernel: None };
    let err = engine.open_decode_opts(&rt.model, "fwd_bf16", &p_buf, 1, &opts).unwrap_err();
    assert!(err.to_string().contains("require paged decode state"), "{err:#}");
    common::cleanup("pgd_opts");
}
