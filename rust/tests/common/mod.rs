//! Shared fixtures for the integration suites.
//!
//! `write_artifacts` materializes a synthetic artifacts dir (manifest only
//! — the reference backend needs no artifact files) from [`SynthSpec`]s,
//! whose knobs cover model size, block kinds, quantization format, and
//! which artifact keys exist. `reference_engine` / `reference_session`
//! wrap it into ready-to-use handles pinned to the reference backend, so
//! the hermetic tier runs identically everywhere — CI containers with no
//! XLA toolchain included.
//!
//! The artifact-backed tier goes through [`real_artifacts_dir`]:
//! `QADX_ARTIFACTS_DIR` when set, else `rust/artifacts` (the `make
//! artifacts` output location). Those tests run *in addition to* the
//! hermetic ones and print an "artifact tier disabled" note (never the
//! "skipping:" marker CI greps for) when artifacts are absent.

#![allow(dead_code)]

use std::path::{Path, PathBuf};

use qadx::api::Session;
use qadx::runtime::{synthetic_manifest_json, BackendKind, Engine, SynthSpec};

/// Write a synthetic artifacts dir (manifest.json only) and return it.
pub fn write_artifacts(tag: &str, specs: &[SynthSpec]) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("qadx_it_{tag}_{}", std::process::id()))
        .join("artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), synthetic_manifest_json(specs)).unwrap();
    dir
}

/// Fresh runs dir next to the artifacts dir of `tag`.
pub fn tmp_runs(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("qadx_it_{tag}_{}", std::process::id()))
        .join("runs");
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Remove the whole `tag` scratch tree.
pub fn cleanup(tag: &str) {
    let dir = std::env::temp_dir().join(format!("qadx_it_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(dir).ok();
}

/// An engine over a synthetic manifest, pinned to the reference backend
/// (hermetic: ignores `QADX_BACKEND`, needs no artifacts, no XLA).
pub fn reference_engine(tag: &str, specs: &[SynthSpec]) -> Engine {
    let dir = write_artifacts(tag, specs);
    Engine::with_backend(&dir, BackendKind::Reference).expect("reference engine")
}

/// A full api::Session over a synthetic manifest on the reference backend.
pub fn reference_session(tag: &str, specs: &[SynthSpec]) -> Session {
    let dir = write_artifacts(tag, specs);
    Session::builder()
        .artifacts_dir(&dir)
        .runs_dir(tmp_runs(tag))
        .backend(BackendKind::Reference)
        .build()
        .expect("reference session")
}

/// The default hermetic model: small, two attention blocks, nvfp4 quant,
/// full artifact key set.
pub fn small_spec(name: &str) -> SynthSpec {
    SynthSpec::small(name)
}

/// Build the deterministic "clock" model (no blocks, one-hot positional
/// rows, identity head): under greedy decode, position p always emits a
/// filler token below position 6 and EOS at/after it, so a row with
/// prompt length L generates exactly 7 - L tokens. Finish times are a
/// pure function of prompt length — ideal for scheduler and chaos-test
/// assertions (serve + fleet suites).
pub fn clock_spec_and_params(name: &str) -> (SynthSpec, Vec<f32>) {
    use qadx::data::tokenizer as tok;
    let mut spec = small_spec(name);
    spec.blocks = vec![];
    spec.n_experts = 0;
    spec.d_model = 16;
    spec.vocab = 16;
    spec.seq_len = 12;
    spec.batch = 4;
    let entry = spec.entry();
    let (d, v, s) = (entry.d_model, entry.vocab, entry.seq_len);
    let mut params = vec![0f32; entry.param_count];
    for def in &entry.params {
        let slice = &mut params[def.offset..def.offset + def.size];
        match def.name.as_str() {
            "pos_emb" => {
                for t in 0..s {
                    let g = if t >= 5 { tok::EOS as usize } else { 5 };
                    slice[t * d + g] = 1.0;
                }
            }
            "ln_f" => slice.fill(1.0),
            "head" => {
                for j in 0..d {
                    slice[j * v + j] = 1.0;
                }
            }
            _ => {}
        }
    }
    (spec, params)
}

/// Where real AOT artifacts live, if any: `QADX_ARTIFACTS_DIR`, else the
/// `make artifacts` location. None disables the artifact-backed tier.
pub fn real_artifacts_dir() -> Option<PathBuf> {
    if let Ok(d) = std::env::var("QADX_ARTIFACTS_DIR") {
        let p = PathBuf::from(d);
        return if p.join("manifest.json").exists() { Some(p) } else { None };
    }
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        None
    }
}

/// Standard note for a disabled artifact tier (deliberately NOT the
/// "skipping:" marker — CI fails on that to catch hermetic-test skips).
pub fn artifact_tier_disabled(test: &str) {
    eprintln!("{test}: artifact tier disabled (no AOT artifacts; set QADX_ARTIFACTS_DIR)");
}
