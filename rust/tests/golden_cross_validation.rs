//! Bit-exact cross-validation of the Rust quant substrate against the JAX
//! oracle (python/compile/kernels/ref.py) via artifacts/golden.json — the
//! contract that the coordinator's PTQ packing computes exactly what the
//! AOT'd fake-quant graphs compute.

mod common;

use std::path::Path;

use qadx::quant::baselines::{int4_fake_quant, mxfp4_fake_quant};
use qadx::quant::fp::{e2m1_round, e4m3_round};
use qadx::quant::nvfp4::{tensor_scale, Nvfp4Tensor};
use qadx::util::json::Json;

/// Golden vectors live next to the AOT artifacts: `QADX_ARTIFACTS_DIR`
/// when set, else the `make artifacts` output dir. Absent goldens disable
/// this (artifact-tier) suite — the codec property tests still run.
fn golden() -> Option<Json> {
    let path = match std::env::var("QADX_ARTIFACTS_DIR") {
        Ok(d) => Path::new(&d).join("golden.json"),
        Err(_) => Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/golden.json"),
    };
    let Ok(text) = std::fs::read_to_string(path) else {
        common::artifact_tier_disabled("golden_cross_validation");
        return None;
    };
    Some(Json::parse(&text).expect("golden.json parses"))
}

fn vec_f32(j: &Json, key: &str) -> Vec<f32> {
    j.req_arr(key)
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect()
}

#[test]
fn e4m3_matches_jax() {
    let Some(g) = golden() else { return };
    let xin = vec_f32(&g, "e4m3_in");
    let want = vec_f32(&g, "e4m3_out");
    for (x, w) in xin.iter().zip(&want) {
        let got = e4m3_round(*x);
        assert!(
            got == *w || (got.is_nan() && w.is_nan()),
            "e4m3({x}) = {got}, jax says {w}"
        );
    }
}

#[test]
fn e2m1_matches_jax() {
    let Some(g) = golden() else { return };
    let xin = vec_f32(&g, "e2m1_in");
    let want = vec_f32(&g, "e2m1_out");
    for (x, w) in xin.iter().zip(&want) {
        assert_eq!(e2m1_round(*x), *w, "e2m1({x})");
    }
}

#[test]
fn nvfp4_codec_matches_jax() {
    let Some(g) = golden() else { return };
    let x = vec_f32(&g, "nvfp4_in");
    let rows = g.req_usize("nvfp4_rows").unwrap();
    let cols = g.req_usize("nvfp4_cols").unwrap();
    let ts_paper = g.req("nvfp4_tensor_scale").unwrap().as_f64().unwrap() as f32;
    assert_eq!(tensor_scale(&x), ts_paper, "tensor scale");

    let q = Nvfp4Tensor::quantize(&x, rows, cols, None);
    let deq = q.dequantize();
    let want_deq = vec_f32(&g, "nvfp4_deq");
    for (i, (a, b)) in deq.iter().zip(&want_deq).enumerate() {
        assert_eq!(a, b, "dequant mismatch at {i}");
    }
    // codes match (golden stores signed grid values)
    let want_codes = vec_f32(&g, "nvfp4_codes");
    for i in 0..x.len() {
        let code = q.code_at(i);
        let mag = qadx::quant::fp::E2M1_GRID[(code & 7) as usize];
        let val = if code & 8 != 0 { -mag } else { mag };
        // jax encodes signed zero as ±0 — compare through abs for zeros
        if want_codes[i] == 0.0 {
            assert_eq!(mag, 0.0, "code mismatch at {i}");
        } else {
            assert_eq!(val, want_codes[i], "code mismatch at {i}");
        }
    }
    // decoded block scales match
    let want_scales = vec_f32(&g, "nvfp4_scales");
    for (b, w) in want_scales.iter().enumerate() {
        let got = qadx::quant::fp::e4m3_decode(q.block_scales[b]);
        assert_eq!(got, *w, "block scale {b}");
    }
}

#[test]
fn mxfp4_and_int4_match_jax() {
    let Some(g) = golden() else { return };
    let x = vec_f32(&g, "nvfp4_in");
    let rows = g.req_usize("nvfp4_rows").unwrap();
    let cols = g.req_usize("nvfp4_cols").unwrap();
    let mx = mxfp4_fake_quant(&x, rows, cols);
    for (i, (a, b)) in mx.iter().zip(vec_f32(&g, "mxfp4_deq")).enumerate() {
        assert!((a - b).abs() <= 1e-6, "mxfp4 mismatch at {i}: {a} vs {b}");
    }
    let i4 = int4_fake_quant(&x, rows, cols);
    for (i, (a, b)) in i4.iter().zip(vec_f32(&g, "int4_deq")).enumerate() {
        assert!((a - b).abs() <= 1e-5, "int4 mismatch at {i}: {a} vs {b}");
    }
}
