//! Chaos tests for `api::fleet` — the deterministic fault-injection
//! oracle. Every test runs hermetically on the reference backend over
//! the "clock" model (`common::clock_spec_and_params`): prompt length L
//! generates exactly 7 - L tokens under greedy decode, so the *exact*
//! token rows are known in advance and bit-identity across fault
//! scenarios is a hard equality, not a statistical claim.
//!
//! The oracle: a fleet run with injected faults (worker kills, seeded
//! prefill/step failures) must resolve every request to the **same
//! row** a no-fault run produces — retries re-prefill on a healthy
//! worker with a per-request RNG stream that depends only on
//! (sample seed, request id). Wall-clock perturbations (injected step
//! latency) and pool thread counts (1 vs 4) must not change a byte.

mod common;

use qadx::api::{
    FaultPlan, FleetCfg, FleetResponse, RequestClass, Saturated, ServeCfg, ServeWeights, Session,
    SlowConsumer, TokenEvent, TokenSink,
};
use qadx::data::tokenizer as tok;
use qadx::runtime::BackendKind;
use qadx::util::pool;
use qadx::util::retry::RetryPolicy;

/// Session over the clock model on the reference backend.
fn clock_session(tag: &str, name: &str) -> (Session, Vec<f32>) {
    let (spec, params) = common::clock_spec_and_params(name);
    let artifacts = common::write_artifacts(tag, &[spec]);
    let session = Session::builder()
        .artifacts_dir(&artifacts)
        .runs_dir(common::tmp_runs(tag))
        .backend(BackendKind::Reference)
        .build()
        .expect("reference session");
    (session, params)
}

/// The row the clock model must produce for `prompt`: fillers (token 5)
/// up to position 6, EOS at 6, PAD tail.
fn expected_row(prompt: &[i32], seq_len: usize) -> Vec<i32> {
    let mut row = vec![tok::PAD; seq_len];
    row[..prompt.len()].copy_from_slice(prompt);
    for p in row.iter_mut().take(6).skip(prompt.len()) {
        *p = 5;
    }
    row[6] = tok::EOS;
    row
}

fn base_cfg(params: &[f32]) -> FleetCfg {
    let mut cfg = FleetCfg::default();
    cfg.sample = qadx::eval::SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 8, seed: 0 };
    cfg.weights = ServeWeights::Params(params.to_vec());
    cfg
}

/// Run one fleet over the clock model: submit `prompts`, drain, shut
/// down. Returns responses sorted by id plus a stats snapshot.
fn run_fleet(
    tag: &str,
    name: &str,
    cfg_fn: impl FnOnce(&mut FleetCfg),
    prompts: &[Vec<i32>],
) -> (Vec<FleetResponse>, qadx::api::FleetStats) {
    let (session, params) = clock_session(tag, name);
    let ms = session.model(name).unwrap();
    let mut cfg = base_cfg(&params);
    cfg_fn(&mut cfg);
    let mut fleet = ms.fleet("fwd_bf16", &cfg).unwrap();
    for p in prompts {
        fleet.submit(p.clone()).unwrap();
    }
    let mut responses = fleet.drain().unwrap();
    responses.sort_by_key(|r| r.id);
    fleet.shutdown();
    let stats = fleet.stats().clone();
    drop(fleet);
    common::cleanup(tag);
    (responses, stats)
}

#[test]
fn worker_killed_mid_generation_is_bit_identical_to_no_fault_run() {
    // Worker 1 dies before its local round 1 — after admitting work and
    // executing one decode round, i.e. mid-generation (every prompt here
    // needs >= 3 rounds). The injected 2 ms round latency keeps both
    // workers busy long enough that the submit burst spreads across
    // them deterministically in practice; correctness does not depend
    // on it. All six requests must resolve to the exact clock rows at
    // both pool thread counts.
    let prompts: Vec<Vec<i32>> =
        vec![vec![1, 4], vec![1, 4, 4], vec![1, 4], vec![1, 4, 4], vec![1, 4], vec![1, 4, 4, 4]];
    let want: Vec<Vec<i32>> = prompts.iter().map(|p| expected_row(p, 12)).collect();

    let (baseline, base_stats) =
        run_fleet("fchaos_base", "clock-fleet", |_| {}, &prompts);
    assert_eq!(baseline.len(), prompts.len());
    assert_eq!(base_stats.worker_deaths, 0);
    for (r, w) in baseline.iter().zip(want.iter()) {
        assert!(r.error.is_none(), "baseline degraded: {:?}", r.error);
        assert_eq!(&r.row, w, "baseline row mismatch for id {}", r.id);
    }

    for threads in [1usize, 4] {
        pool::set_threads(threads);
        let tag = format!("fchaos_kill_t{threads}");
        let (chaos, stats) = run_fleet(
            &tag,
            "clock-fleet",
            |cfg| {
                cfg.fault = FaultPlan {
                    seed: 1,
                    kills: vec![(1, 1)],
                    step_delay_ms: 2.0,
                    ..FaultPlan::default()
                };
            },
            &prompts,
        );
        pool::set_threads(0);
        assert_eq!(chaos.len(), prompts.len(), "threads={threads}");
        for (r, w) in chaos.iter().zip(want.iter()) {
            assert!(
                r.error.is_none(),
                "threads={threads} id {} degraded: {:?}",
                r.id,
                r.error
            );
            assert_eq!(
                &r.row, w,
                "threads={threads}: chaos row differs from no-fault run for id {}",
                r.id
            );
        }
        assert_eq!(stats.worker_deaths, 1, "threads={threads}: {}", stats.summary());
        assert!(
            stats.per_worker[1].dead,
            "threads={threads}: worker 1 must be marked dead"
        );
        assert!(
            stats.retries >= 1,
            "threads={threads}: the dead worker's requests must requeue: {}",
            stats.summary()
        );
        // every retried request finished on the surviving worker
        for r in chaos.iter().filter(|r| r.attempt > 0) {
            assert_eq!(r.worker, Some(0), "threads={threads}");
        }
    }
}

#[test]
fn seeded_prefill_faults_retry_to_bit_identical_rows() {
    // FaultPlan's coins are pure functions of (seed, id, attempt), so
    // the test can precompute exactly which attempts fail and assert
    // the retry counter matches — and the retried generations must
    // still be the exact clock rows (per-request RNG excludes the
    // attempt number).
    let plan = FaultPlan { seed: 2, prefill_fail_p: 0.35, ..FaultPlan::default() };
    let n = 8u64;
    let mut expected_retries = 0usize;
    let mut ids_retried = 0usize;
    for id in 0..n {
        let first_pass =
            (0..4).find(|&a| !plan.fail_prefill(id, a)).expect("seed 2 passes within budget");
        expected_retries += first_pass as usize;
        ids_retried += usize::from(first_pass > 0);
    }
    assert!(ids_retried >= 3, "seed 2 must actually inject failures");
    assert!(expected_retries >= ids_retried);

    let prompts: Vec<Vec<i32>> = (0..n).map(|_| vec![1, 4, 4]).collect();
    let want = expected_row(&[1, 4, 4], 12);
    let (responses, stats) = run_fleet(
        "fchaos_prefill",
        "clock-fleet",
        |cfg| cfg.fault = plan.clone(),
        &prompts,
    );
    assert_eq!(responses.len(), prompts.len());
    for r in &responses {
        assert!(r.error.is_none(), "id {} degraded: {:?}", r.id, r.error);
        assert_eq!(r.row, want, "retried row differs for id {}", r.id);
        let first_pass = (0..4).find(|&a| !plan.fail_prefill(r.id, a)).unwrap();
        assert_eq!(r.attempt, first_pass, "id {} resolved on the wrong attempt", r.id);
    }
    assert_eq!(stats.retries, expected_retries, "{}", stats.summary());
    assert_eq!(stats.worker_deaths, 0);
    assert_eq!(stats.degraded, 0);
}

#[test]
fn step_fault_budget_exhaustion_degrades_deterministically() {
    // step_fail_p = 1.0 fails every decode step, so every attempt dies
    // mid-generation and the retry budget (2) is spent exactly:
    // attempts 0, 1, 2 all fail -> degraded response, prompt-only row,
    // never a hang. Telemetry must carry the retry trail.
    let tel = std::env::temp_dir()
        .join(format!("qadx_fchaos_budget_tel_{}.jsonl", std::process::id()));
    std::fs::remove_file(&tel).ok(); // the appender appends; start clean
    let prompts: Vec<Vec<i32>> = (0..4).map(|_| vec![1, 4]).collect();
    let (responses, stats) = run_fleet(
        "fchaos_budget",
        "clock-fleet",
        |cfg| {
            cfg.fault = FaultPlan { step_fail_p: 1.0, ..FaultPlan::default() };
            cfg.retry = RetryPolicy { base_ms: 0.5, cap_ms: 2.0, max_attempts: 2 };
            cfg.telemetry = Some(tel.clone());
        },
        &prompts,
    );
    assert_eq!(responses.len(), 4);
    for r in &responses {
        let err = r.error.as_deref().unwrap_or("");
        assert!(
            err.contains("retry budget exhausted after 2 attempts"),
            "id {}: {err:?}",
            r.id
        );
        assert_eq!(r.attempt, 2, "id {}", r.id);
        assert_eq!(r.gen_tokens, 0);
        let mut want = vec![tok::PAD; 12];
        want[..2].copy_from_slice(&[1, 4]);
        assert_eq!(r.row, want, "degraded row must be the prompt, PAD-tailed");
    }
    assert_eq!(stats.degraded, 4, "{}", stats.summary());
    assert_eq!(stats.retries, 8, "2 retries per request: {}", stats.summary());
    assert_eq!(stats.completed, 4);
    let failures: usize = stats.per_worker.iter().map(|w| w.failures).sum();
    assert_eq!(failures, 12, "3 failed attempts per request");
    let log = std::fs::read_to_string(&tel).expect("telemetry JSONL written");
    let retries = log.lines().filter(|l| l.contains("\"event\":\"retry\"")).count();
    assert_eq!(retries, 8, "{log}");
    assert!(log.contains("\"backoff_ms\""), "{log}");
    assert!(log.contains("\"event\":\"fleet\""), "{log}");
    std::fs::remove_file(&tel).ok();
}

#[test]
fn saturated_router_sheds_with_retry_after_and_recovers() {
    // One worker, one slot, queue cap 2, slow rounds (5 ms): the fourth
    // submit must shed with the typed Saturated error while the first
    // three resolve; after the drain the router accepts work again.
    let tel =
        std::env::temp_dir().join(format!("qadx_fchaos_sat_tel_{}.jsonl", std::process::id()));
    std::fs::remove_file(&tel).ok(); // the appender appends; start clean
    let (session, params) = clock_session("fchaos_sat", "clock-fleet");
    let ms = session.model("clock-fleet").unwrap();
    let mut cfg = base_cfg(&params);
    cfg.workers = 1;
    cfg.max_slots = 1;
    cfg.queue_cap = 2;
    cfg.telemetry = Some(tel.clone());
    cfg.fault = FaultPlan { step_delay_ms: 5.0, ..FaultPlan::default() };
    let mut fleet = ms.fleet("fwd_bf16", &cfg).unwrap();

    for _ in 0..3 {
        fleet.submit(vec![1, 4]).unwrap();
    }
    assert_eq!(fleet.queued(), 2, "slot holds one, two wait in the router");
    let err = fleet.submit(vec![1, 4]).expect_err("queue is at cap");
    let sat = err.downcast_ref::<Saturated>().expect("typed Saturated through anyhow");
    assert!(sat.retry_after_ms >= 1.0, "hint: {}", sat.retry_after_ms);
    assert_eq!(fleet.stats().shed, 1);

    let responses = fleet.drain().unwrap();
    assert_eq!(responses.len(), 3);
    let want = expected_row(&[1, 4], 12);
    for r in &responses {
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.row, want);
    }
    // recovery: the queue drained, so admission accepts again
    fleet.submit(vec![1, 4]).expect("router must recover after drain");
    let responses = fleet.drain().unwrap();
    assert_eq!(responses.len(), 1);
    assert!(responses[0].error.is_none());
    assert_eq!(fleet.stats().shed, 1, "no further sheds");
    assert!((fleet.stats().shed_rate() - 0.2).abs() < 1e-12, "1 shed of 5 offered");
    fleet.shutdown();
    drop(fleet);
    // A saturated run must never leak a bare NaN/inf token into the
    // JSONL stream (empty stats windows serialize as null): every line
    // — reject events included — stays parseable JSON.
    let log = std::fs::read_to_string(&tel).expect("telemetry JSONL written");
    assert!(log.contains("\"event\":\"reject\""), "{log}");
    assert!(log.contains("\"event\":\"fleet\""), "{log}");
    for l in log.lines() {
        assert!(!l.contains("NaN"), "bare NaN leaked into telemetry: {l}");
        assert!(
            qadx::util::json::Json::parse(l).is_ok(),
            "unparseable telemetry line: {l}"
        );
    }
    std::fs::remove_file(&tel).ok();
    common::cleanup("fchaos_sat");
}

#[test]
fn zero_deadline_expires_queued_requests_without_hanging() {
    // deadline 0 with an unseeded service estimator: admission bounds
    // the router queue by live slot capacity (1 here), so the dispatched
    // request plus one queued request admit and anything beyond sheds.
    // The queued request then expires at its 0 ms deadline — a degraded
    // response, not a hang; the dispatched one is the worker's to finish
    // and completes normally.
    let (session, params) = clock_session("fchaos_ddl", "clock-fleet");
    let ms = session.model("clock-fleet").unwrap();
    let mut cfg = base_cfg(&params);
    cfg.workers = 1;
    cfg.max_slots = 1;
    cfg.deadline_ms = Some(0.0);
    cfg.fault = FaultPlan { step_delay_ms: 5.0, ..FaultPlan::default() };
    let mut fleet = ms.fleet("fwd_bf16", &cfg).unwrap();
    let first = fleet.submit(vec![1, 4]).unwrap(); // dispatched immediately
    let queued = fleet.submit(vec![1, 4]).unwrap(); // router-queued (1 = live capacity)
    let err = fleet.submit(vec![1, 4]).expect_err("beyond capacity while unseeded");
    assert!(err.downcast_ref::<Saturated>().is_some(), "{err:#}");
    let mut responses = fleet.drain().unwrap();
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), 2, "drain resolves everything admitted — no hang");
    let by_id = |id: u64| responses.iter().find(|r| r.id == id).unwrap();
    assert!(by_id(first).error.is_none(), "dispatched request finishes");
    assert_eq!(by_id(first).row, expected_row(&[1, 4], 12));
    let e = by_id(queued).error.as_deref().unwrap_or("");
    assert!(e.contains("deadline exceeded"), "{e:?}");
    assert_eq!(by_id(queued).gen_tokens, 0);
    assert_eq!(fleet.stats().expired, 1, "{}", fleet.stats().summary());
    assert_eq!(fleet.stats().shed, 1, "{}", fleet.stats().summary());
    fleet.shutdown();
    drop(fleet);
    common::cleanup("fchaos_ddl");
}

#[test]
fn unseeded_deadline_admission_bounds_by_live_capacity() {
    // Regression: `est_service_ms` defaults to 0.0 and the EWMA only
    // seeds after the first completion, so the wait-estimate admission
    // test (0 > deadline) used to admit an unbounded backlog during
    // warm-up. Until the estimator seeds, admission is bounded by live
    // slot capacity: with 1 worker x 2 slots, two requests dispatch, two
    // queue, the rest shed with the typed Saturated error — and
    // everything admitted still resolves to exact clock rows.
    let (session, params) = clock_session("fchaos_seed", "clock-fleet");
    let ms = session.model("clock-fleet").unwrap();
    let mut cfg = base_cfg(&params);
    cfg.workers = 1;
    cfg.max_slots = 2;
    cfg.deadline_ms = Some(1e9); // generous: only the unseeded bound can shed
    cfg.fault = FaultPlan { step_delay_ms: 5.0, ..FaultPlan::default() };
    let mut fleet = ms.fleet("fwd_bf16", &cfg).unwrap();
    let mut admitted = 0usize;
    let mut shed = 0usize;
    for _ in 0..6 {
        match fleet.submit(vec![1, 4]) {
            Ok(_) => admitted += 1,
            Err(e) => {
                let sat = e.downcast_ref::<Saturated>().expect("typed Saturated");
                assert!(sat.retry_after_ms >= 1.0, "hint: {}", sat.retry_after_ms);
                shed += 1;
            }
        }
    }
    assert_eq!(admitted, 4, "2 dispatched + 2 queued (live slot capacity)");
    assert_eq!(shed, 2);
    assert_eq!(fleet.stats().shed, 2);
    let responses = fleet.drain().unwrap();
    assert_eq!(responses.len(), 4);
    let want = expected_row(&[1, 4], 12);
    for r in &responses {
        assert!(r.error.is_none(), "id {}: {:?}", r.id, r.error);
        assert_eq!(r.row, want);
    }
    assert_eq!(fleet.stats().expired, 0, "nothing expires under a generous deadline");
    // Seeded now: the wait-estimate path takes over and admits again.
    fleet.submit(vec![1, 4]).expect("seeded estimator admits under a generous deadline");
    let responses = fleet.drain().unwrap();
    assert_eq!(responses.len(), 1);
    assert!(responses[0].error.is_none());
    fleet.shutdown();
    drop(fleet);
    common::cleanup("fchaos_seed");
}

#[test]
fn fleet_relays_token_events_through_router_and_telemetry() {
    // Token streaming across the worker boundary: workers emit Token
    // events, the router relays them to the `on_token` sink and (with
    // `stream`) to JSONL. The clock model fixes every sequence: prompt
    // length L yields 7 - L tokens, fillers then EOS, indices from 0.
    use std::cell::RefCell;
    use std::rc::Rc;

    let tel =
        std::env::temp_dir().join(format!("qadx_fchaos_stream_tel_{}.jsonl", std::process::id()));
    std::fs::remove_file(&tel).ok(); // the appender appends; start clean
    let (session, params) = clock_session("fchaos_stream", "clock-fleet");
    let ms = session.model("clock-fleet").unwrap();
    let events: Rc<RefCell<Vec<TokenEvent>>> = Rc::new(RefCell::new(Vec::new()));
    let sink_events = events.clone();
    let mut cfg = base_cfg(&params);
    cfg.workers = 1;
    cfg.max_slots = 2;
    cfg.stream = true;
    cfg.telemetry = Some(tel.clone());
    cfg.on_token = Some(TokenSink::new(move |ev| sink_events.borrow_mut().push(*ev)));
    let mut fleet = ms.fleet("fwd_bf16", &cfg).unwrap();
    let a = fleet.submit(vec![1, 4, 4, 4]).unwrap(); // 3 tokens: 5, 5, EOS
    let b = fleet.submit(vec![1, 4]).unwrap(); //        5 tokens
    let mut responses = fleet.drain().unwrap();
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), 2);
    fleet.shutdown();
    drop(fleet);

    let events = events.borrow();
    for r in &responses {
        let seq: Vec<&TokenEvent> = events.iter().filter(|e| e.id == r.id).collect();
        assert_eq!(seq.len(), r.gen_tokens, "one event per generated token (id {})", r.id);
        for (i, ev) in seq.iter().enumerate() {
            assert_eq!(ev.index, i, "contiguous indices per request (id {})", r.id);
            assert_eq!(ev.attempt, 0, "no retries in this run");
            assert_eq!(Some(ev.worker), r.worker, "events name the generating worker");
        }
    }
    let toks_a: Vec<i32> = events.iter().filter(|e| e.id == a).map(|e| e.token).collect();
    assert_eq!(toks_a, vec![5, 5, tok::EOS]);
    let toks_b: Vec<i32> = events.iter().filter(|e| e.id == b).map(|e| e.token).collect();
    assert_eq!(toks_b, vec![5, 5, 5, 5, tok::EOS]);

    let log = std::fs::read_to_string(&tel).expect("telemetry JSONL written");
    let token_lines: Vec<&str> =
        log.lines().filter(|l| l.contains("\"event\":\"token\"")).collect();
    assert_eq!(token_lines.len(), events.len(), "{log}");
    assert!(token_lines.iter().all(|l| l.contains("\"worker\"")), "{log}");
    std::fs::remove_file(&tel).ok();
    common::cleanup("fchaos_stream");
}

#[test]
fn single_engine_serve_queue_bound_sheds_and_recovers() {
    // Satellite: the same Saturated contract on the single-engine
    // ServeHandle — max_queue bounds the *waiting* queue (in-flight
    // slots excluded), the error downcasts, and the handle keeps
    // serving afterwards. Fully single-threaded, so exact.
    let (spec, params) = common::clock_spec_and_params("clock-serveq");
    let artifacts = common::write_artifacts("fchaos_sq", &[spec]);
    let session = Session::builder()
        .artifacts_dir(&artifacts)
        .runs_dir(common::tmp_runs("fchaos_sq"))
        .backend(BackendKind::Reference)
        .build()
        .unwrap();
    let ms = session.model("clock-serveq").unwrap();
    let mut cfg = ServeCfg::default();
    cfg.sample = qadx::eval::SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 8, seed: 0 };
    cfg.weights = ServeWeights::Params(params);
    cfg.max_slots = 1;
    cfg.max_queue = 1;
    let mut server = ms.server("fwd_bf16", &cfg).unwrap();
    server.submit(vec![1, 4]).unwrap(); //        admitted into the slot
    server.submit(vec![1, 4, 4]).unwrap(); //     queued (1 = cap)
    let err = server.submit(vec![1, 4]).expect_err("queue bound");
    let sat = err.downcast_ref::<Saturated>().expect("typed Saturated through anyhow");
    assert!(sat.retry_after_ms >= 1.0);
    assert_eq!(server.stats().shed, 1);
    let responses = server.drain().unwrap();
    assert_eq!(responses.len(), 2, "shed request never entered the queue");
    // recovers: drained queue admits again
    server.submit(vec![1, 4]).unwrap();
    assert_eq!(server.drain().unwrap().len(), 1);
    assert_eq!(server.stats().shed, 1);
    common::cleanup("fchaos_sq");
}

#[test]
fn combined_chaos_kill_step_faults_and_stalled_consumer_stay_bit_identical() {
    // The full fault stack at once: worker 1 dies before its round 1,
    // every decode step flips a seeded fault coin, the streaming
    // consumer deliberately stalls on request 0's tokens (1 ms each
    // against capacity-1 DropOldest channels), and traffic is mixed
    // interactive/batch. None of it may move a byte: every resolved row
    // equals the no-fault clock oracle at both pool thread counts, every
    // streamed token — from any attempt, around any drop — matches the
    // oracle at its index, and the paged decode state drains to zero.
    use std::cell::RefCell;
    use std::rc::Rc;

    let prompts: Vec<Vec<i32>> =
        vec![vec![1, 4], vec![1, 4, 4], vec![1, 4], vec![1, 4, 4], vec![1, 4], vec![1, 4, 4, 4]];
    let classes = [
        RequestClass::Interactive,
        RequestClass::Batch,
        RequestClass::Interactive,
        RequestClass::Batch,
        RequestClass::Interactive,
        RequestClass::Batch,
    ];
    let want: Vec<Vec<i32>> = prompts.iter().map(|p| expected_row(p, 12)).collect();

    for threads in [1usize, 4] {
        pool::set_threads(threads);
        let tag = format!("fchaos_combo_t{threads}");
        let (session, params) = clock_session(&tag, "clock-fleet");
        let ms = session.model("clock-fleet").unwrap();
        let events: Rc<RefCell<Vec<TokenEvent>>> = Rc::new(RefCell::new(Vec::new()));
        let sink_events = events.clone();
        let mut cfg = base_cfg(&params);
        cfg.workers = 2;
        cfg.fault = FaultPlan {
            seed: 11,
            kills: vec![(1, 1)],
            step_fail_p: 0.1,
            step_delay_ms: 2.0,
            ..FaultPlan::default()
        };
        // generous budget: the seeded step faults plus the death requeue
        // must never exhaust it — bit-identity is the oracle here, so a
        // degraded response is a test failure, not an acceptable outcome
        cfg.retry = RetryPolicy { base_ms: 0.1, cap_ms: 1.0, max_attempts: 12 };
        cfg.stream_buf = 1;
        cfg.slow_consumer = SlowConsumer::DropOldest;
        cfg.on_token = Some(TokenSink::new(move |ev| {
            if ev.id == 0 {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            sink_events.borrow_mut().push(*ev);
        }));
        let mut fleet = ms.fleet("fwd_bf16", &cfg).unwrap();
        for (p, class) in prompts.iter().zip(classes.iter()) {
            fleet.submit_class(p.clone(), *class).unwrap();
        }
        let mut responses = fleet.drain().unwrap();
        responses.sort_by_key(|r| r.id);
        fleet.shutdown();
        let stats = fleet.stats().clone();
        drop(fleet);
        common::cleanup(&tag);
        pool::set_threads(0);

        assert_eq!(responses.len(), prompts.len(), "threads={threads}");
        for (r, w) in responses.iter().zip(want.iter()) {
            assert!(
                r.error.is_none(),
                "threads={threads} id {} degraded: {:?}",
                r.id,
                r.error
            );
            assert_eq!(
                &r.row, w,
                "threads={threads}: chaos row differs from no-fault oracle for id {}",
                r.id
            );
        }
        assert_eq!(stats.worker_deaths, 1, "threads={threads}: {}", stats.summary());
        assert!(
            stats.retries >= 1,
            "threads={threads}: the dead worker's requests must requeue: {}",
            stats.summary()
        );
        // Every streamed token agrees with the oracle at its index —
        // retried attempts replay the same per-request stream, so even a
        // token pushed by a later-faulted attempt matches the prefix.
        let events = events.borrow();
        for ev in events.iter() {
            let plen = prompts[ev.id as usize].len();
            assert_eq!(
                ev.token,
                want[ev.id as usize][plen + ev.index],
                "threads={threads}: streamed token diverges (id {} index {})",
                ev.id,
                ev.index
            );
        }
        // Conservation: every pushed token was either delivered to the
        // sink or counted dropped by its channel (retried attempts can
        // only push extra tokens, never lose one uncounted).
        let gen_total: usize = responses.iter().map(|r| r.gen_tokens).sum();
        assert!(
            events.len() as u64 + stats.tokens_dropped >= gen_total as u64,
            "threads={threads}: delivered {} + dropped {} < generated {gen_total}",
            events.len(),
            stats.tokens_dropped
        );
        // zero leaked pages after a full drain (the killed worker never
        // reports a shutdown snapshot; its default slice stays 0)
        for (w, ws) in stats.per_worker.iter().enumerate() {
            assert_eq!(ws.live_pages, 0, "threads={threads}: worker {w} leaked pages");
        }
    }
}

#[test]
fn starvation_bound_bypass_count_is_exact_under_a_seeded_schedule() {
    // One worker x one slot and a 20 ms round delay: all six submits land
    // while the slot is busy with id 0, so the lane state is frozen and
    // the dispatch order is pure policy. With bound 2 the schedule is
    // forced: I0 (slot at submit), I1, I3, then B2 via the bypass — the
    // only time batch jumps while interactive waits — then I4, then B5
    // from an empty interactive lane (which charges no bypass).
    let (session, params) = clock_session("fchaos_bypass", "clock-fleet");
    let ms = session.model("clock-fleet").unwrap();
    let mut cfg = base_cfg(&params);
    cfg.workers = 1;
    cfg.max_slots = 1;
    cfg.starvation_bound = 2;
    cfg.fault = FaultPlan { step_delay_ms: 20.0, ..FaultPlan::default() };
    let mut fleet = ms.fleet("fwd_bf16", &cfg).unwrap();
    let classes = [
        RequestClass::Interactive, // 0: straight into the slot
        RequestClass::Interactive, // 1
        RequestClass::Batch,       // 2
        RequestClass::Interactive, // 3
        RequestClass::Interactive, // 4
        RequestClass::Batch,       // 5
    ];
    for class in classes {
        fleet.submit_class(vec![1, 4], class).unwrap();
    }
    assert_eq!(fleet.lane_depths(), (3, 2), "id 0 holds the slot, five queue behind it");
    // drain resolves in dispatch order (single slot, sequential service)
    let responses = fleet.drain().unwrap();
    let order: Vec<u64> = responses.iter().map(|r| r.id).collect();
    assert_eq!(order, vec![0, 1, 3, 2, 4, 5], "dispatch order must be pure lane policy");
    let want = expected_row(&[1, 4], 12);
    for r in &responses {
        assert!(r.error.is_none(), "id {}: {:?}", r.id, r.error);
        assert_eq!(r.row, want, "id {}", r.id);
    }
    let stats = fleet.stats();
    assert_eq!(stats.lane_bypasses, 1, "exactly one bounded bypass: {}", stats.summary());
    assert_eq!(stats.per_class.interactive.requests, 4);
    assert_eq!(stats.per_class.batch.requests, 2);
    assert_eq!(stats.per_class.interactive.gen_tokens, 20, "4 requests x 5 tokens");
    assert_eq!(stats.per_class.batch.gen_tokens, 10);
    fleet.shutdown();
    drop(fleet);
    common::cleanup("fchaos_bypass");
}

#[test]
fn interactive_admission_evicts_youngest_batch_before_shedding() {
    // The middle rung of the degradation ladder: at queue cap, a batch
    // arrival sheds outright, but an interactive arrival first evicts
    // the youngest *queued* batch request — which degrades with an
    // explicit error instead of silently disappearing.
    let (session, params) = clock_session("fchaos_evict", "clock-fleet");
    let ms = session.model("clock-fleet").unwrap();
    let mut cfg = base_cfg(&params);
    cfg.workers = 1;
    cfg.max_slots = 1;
    cfg.queue_cap = 1;
    cfg.fault = FaultPlan { step_delay_ms: 20.0, ..FaultPlan::default() };
    let mut fleet = ms.fleet("fwd_bf16", &cfg).unwrap();
    let b0 = fleet.submit_class(vec![1, 4], RequestClass::Batch).unwrap(); // slot
    let b1 = fleet.submit_class(vec![1, 4], RequestClass::Batch).unwrap(); // queued (cap 1)
    let err = fleet
        .submit_class(vec![1, 4], RequestClass::Batch)
        .expect_err("batch at cap sheds, never evicts");
    assert!(err.downcast_ref::<Saturated>().is_some(), "{err:#}");
    assert_eq!(fleet.stats().shed, 1);
    assert_eq!(fleet.stats().evicted, 0);
    let i2 = fleet
        .submit_class(vec![1, 4], RequestClass::Interactive)
        .expect("interactive takes the evicted batch request's queue slot");
    assert_eq!(fleet.stats().evicted, 1);
    assert_eq!(fleet.stats().shed, 1, "the eviction replaced a shed");
    let mut responses = fleet.drain().unwrap();
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), 3);
    let by_id = |id: u64| responses.iter().find(|r| r.id == id).unwrap();
    let want = expected_row(&[1, 4], 12);
    for id in [b0, i2] {
        assert!(by_id(id).error.is_none(), "id {id}: {:?}", by_id(id).error);
        assert_eq!(by_id(id).row, want, "id {id}");
    }
    let e = by_id(b1).error.as_deref().unwrap_or("");
    assert!(e.contains("evicted by interactive admission"), "{e:?}");
    assert_eq!(by_id(b1).gen_tokens, 0);
    assert_eq!(fleet.stats().per_class.batch.evicted, 1);
    assert_eq!(fleet.stats().degraded, 1, "{}", fleet.stats().summary());
    fleet.shutdown();
    drop(fleet);
    common::cleanup("fchaos_evict");
}

#[test]
fn expired_requests_leave_exactly_one_terminal_record_per_id() {
    // Stream/response parity: every admitted request — completed or
    // expired while queued — leaves exactly one terminal "request" JSONL
    // event whose id matches exactly one response; expiries additionally
    // leave a class-labeled "expired" event, and the shed submission
    // (which never got an id) leaves a "reject" event instead.
    let tel = std::env::temp_dir()
        .join(format!("qadx_fchaos_parity_tel_{}.jsonl", std::process::id()));
    std::fs::remove_file(&tel).ok(); // the appender appends; start clean
    let (session, params) = clock_session("fchaos_parity", "clock-fleet");
    let ms = session.model("clock-fleet").unwrap();
    let mut cfg = base_cfg(&params);
    cfg.workers = 1;
    cfg.max_slots = 1;
    cfg.deadline_ms = Some(0.0);
    cfg.telemetry = Some(tel.clone());
    cfg.fault = FaultPlan { step_delay_ms: 5.0, ..FaultPlan::default() };
    let mut fleet = ms.fleet("fwd_bf16", &cfg).unwrap();
    let done = fleet.submit_class(vec![1, 4], RequestClass::Interactive).unwrap(); // slot
    let qb = fleet.submit_class(vec![1, 4], RequestClass::Batch).unwrap(); // queued
    let qi = fleet.submit_class(vec![1, 4], RequestClass::Interactive).unwrap(); // queued
    let err = fleet
        .submit_class(vec![1, 4], RequestClass::Interactive)
        .expect_err("beyond live capacity while the estimator is unseeded");
    assert!(err.downcast_ref::<Saturated>().is_some(), "{err:#}");
    let mut responses = fleet.drain().unwrap();
    responses.sort_by_key(|r| r.id);
    fleet.shutdown();
    let stats = fleet.stats().clone();
    drop(fleet);

    assert_eq!(responses.len(), 3, "everything admitted resolves");
    let by_id = |id: u64| responses.iter().find(|r| r.id == id).unwrap();
    assert!(by_id(done).error.is_none(), "{:?}", by_id(done).error);
    assert_eq!(by_id(done).row, expected_row(&[1, 4], 12));
    for id in [qb, qi] {
        let e = by_id(id).error.as_deref().unwrap_or("");
        assert!(e.contains("deadline exceeded"), "id {id}: {e:?}");
        assert_eq!(by_id(id).gen_tokens, 0, "id {id}");
    }
    assert_eq!(stats.expired, 2, "{}", stats.summary());
    assert_eq!(stats.per_class.interactive.expired, 1);
    assert_eq!(stats.per_class.batch.expired, 1);
    assert_eq!(stats.shed, 1);
    assert_eq!(stats.per_class.interactive.shed, 1);

    let log = std::fs::read_to_string(&tel).expect("telemetry JSONL written");
    let mut terminal: std::collections::BTreeMap<u64, usize> = std::collections::BTreeMap::new();
    let mut expired_classes: Vec<String> = Vec::new();
    let mut rejects = 0usize;
    for line in log.lines() {
        let j = qadx::util::json::Json::parse(line)
            .unwrap_or_else(|e| panic!("unparseable telemetry line {line:?}: {e:?}"));
        match j.get("event").and_then(|e| e.as_str()) {
            Some("request") => {
                let id = j.get("id").and_then(|v| v.as_f64()).expect("request has id") as u64;
                *terminal.entry(id).or_insert(0) += 1;
                assert!(
                    j.get("class").and_then(|c| c.as_str()).is_some(),
                    "terminal event carries its class: {line}"
                );
            }
            Some("expired") => {
                let class = j.get("class").and_then(|c| c.as_str()).expect("expired has class");
                expired_classes.push(class.to_string());
            }
            Some("reject") => rejects += 1,
            _ => {}
        }
    }
    // parity: terminal records and responses are the same id multiset
    assert_eq!(terminal.len(), responses.len(), "{log}");
    for r in &responses {
        assert_eq!(terminal.get(&r.id), Some(&1), "id {} terminal records: {log}", r.id);
    }
    // the interactive lane is scanned before the batch lane
    assert_eq!(expired_classes, vec!["interactive", "batch"], "{log}");
    assert_eq!(rejects, 1, "{log}");
    std::fs::remove_file(&tel).ok();
    common::cleanup("fchaos_parity");
}

#[test]
fn drop_oldest_keeps_workers_unblocked_and_conserves_tokens() {
    // Capacity-1 DropOldest channels and a router that never polls while
    // both slots generate: the worker must never block (zero stalls),
    // every token is either delivered or counted dropped — exact
    // conservation, no faults or retries here — and the freshest tail
    // (the EOS) always survives the drops.
    use std::cell::RefCell;
    use std::rc::Rc;

    let (session, params) = clock_session("fchaos_drop", "clock-fleet");
    let ms = session.model("clock-fleet").unwrap();
    let events: Rc<RefCell<Vec<TokenEvent>>> = Rc::new(RefCell::new(Vec::new()));
    let sink_events = events.clone();
    let mut cfg = base_cfg(&params);
    cfg.workers = 1;
    cfg.max_slots = 2;
    cfg.stream_buf = 1;
    cfg.slow_consumer = SlowConsumer::DropOldest;
    cfg.fault = FaultPlan { step_delay_ms: 5.0, ..FaultPlan::default() };
    cfg.on_token = Some(TokenSink::new(move |ev| sink_events.borrow_mut().push(*ev)));
    let mut fleet = ms.fleet("fwd_bf16", &cfg).unwrap();
    let slow = fleet.submit(vec![1, 4]).unwrap(); //       5 tokens
    let brisk = fleet.submit(vec![1, 4, 4, 4]).unwrap(); // 3 tokens
    let mut responses = fleet.drain().unwrap();
    responses.sort_by_key(|r| r.id);
    fleet.shutdown();
    let stats = fleet.stats().clone();
    drop(fleet);
    common::cleanup("fchaos_drop");

    assert_eq!(responses.len(), 2);
    let by_id = |id: u64| responses.iter().find(|r| r.id == id).unwrap();
    assert_eq!(by_id(slow).row, expected_row(&[1, 4], 12));
    assert_eq!(by_id(brisk).row, expected_row(&[1, 4, 4, 4], 12));
    assert!(responses.iter().all(|r| r.error.is_none()));

    let events = events.borrow();
    let gen_total: usize = responses.iter().map(|r| r.gen_tokens).sum();
    assert_eq!(
        events.len() as u64 + stats.tokens_dropped,
        gen_total as u64,
        "conservation: delivered {} + dropped {} != generated {gen_total}",
        events.len(),
        stats.tokens_dropped
    );
    assert!(stats.tokens_dropped >= 2, "{}", stats.summary());
    assert_eq!(stats.consumer_stalls, 0, "DropOldest never blocks a worker");
    assert_eq!(stats.streams_disconnected, 0);
    for id in [slow, brisk] {
        let last = events.iter().filter(|e| e.id == id).next_back().expect("some delivery");
        assert_eq!(last.token, tok::EOS, "the freshest tail survives (id {id})");
    }
}

#[test]
fn disconnect_policy_severs_the_stream_but_finishes_the_request() {
    // Fail-fast rung: the first overflow severs request 0's stream — the
    // counters record exactly one disconnection, conservation still
    // holds (post-sever pushes count as drops) — while the generation
    // itself completes bit-identically, untouched by its dead stream.
    use std::cell::RefCell;
    use std::rc::Rc;

    let (session, params) = clock_session("fchaos_disc", "clock-fleet");
    let ms = session.model("clock-fleet").unwrap();
    let events: Rc<RefCell<Vec<TokenEvent>>> = Rc::new(RefCell::new(Vec::new()));
    let sink_events = events.clone();
    let mut cfg = base_cfg(&params);
    cfg.workers = 1;
    cfg.max_slots = 1;
    cfg.stream_buf = 1;
    cfg.slow_consumer = SlowConsumer::Disconnect;
    cfg.fault = FaultPlan { step_delay_ms: 5.0, ..FaultPlan::default() };
    cfg.on_token = Some(TokenSink::new(move |ev| sink_events.borrow_mut().push(*ev)));
    let mut fleet = ms.fleet("fwd_bf16", &cfg).unwrap();
    let id = fleet.submit(vec![1, 4]).unwrap();
    let responses = fleet.drain().unwrap();
    fleet.shutdown();
    let stats = fleet.stats().clone();
    drop(fleet);
    common::cleanup("fchaos_disc");

    assert_eq!(responses.len(), 1);
    assert!(responses[0].error.is_none(), "{:?}", responses[0].error);
    assert_eq!(responses[0].id, id);
    assert_eq!(responses[0].row, expected_row(&[1, 4], 12));
    assert_eq!(responses[0].gen_tokens, 5);

    let events = events.borrow();
    assert_eq!(stats.streams_disconnected, 1, "{}", stats.summary());
    assert_eq!(
        events.len() as u64 + stats.tokens_dropped,
        5,
        "conservation across the sever: delivered {} + dropped {}",
        events.len(),
        stats.tokens_dropped
    );
    assert!(events.len() <= 2, "nothing delivered after the sever: {events:?}");
}

#[test]
fn single_engine_lanes_dispatch_interactive_first_with_exact_bypass() {
    // The same lane policy on the single-engine scheduler, fully
    // single-threaded and therefore exact: with one slot and bound 1 the
    // admission order is forced — I0 (slot at submit), I2, then B1 via
    // the bypass, I4, then B3 from an empty interactive lane (no bypass
    // charged) — and per-class stats split accordingly.
    let (spec, params) = common::clock_spec_and_params("clock-lanes");
    let artifacts = common::write_artifacts("fchaos_lanes", &[spec]);
    let session = Session::builder()
        .artifacts_dir(&artifacts)
        .runs_dir(common::tmp_runs("fchaos_lanes"))
        .backend(BackendKind::Reference)
        .build()
        .unwrap();
    let ms = session.model("clock-lanes").unwrap();
    let mut cfg = ServeCfg::default();
    cfg.sample = qadx::eval::SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 8, seed: 0 };
    cfg.weights = ServeWeights::Params(params);
    cfg.max_slots = 1;
    cfg.starvation_bound = 1;
    let mut server = ms.server("fwd_bf16", &cfg).unwrap();
    let classes = [
        RequestClass::Interactive, // 0: straight into the slot
        RequestClass::Batch,       // 1
        RequestClass::Interactive, // 2
        RequestClass::Batch,       // 3
        RequestClass::Interactive, // 4
    ];
    for class in classes {
        server.submit_class(vec![1, 4], class).unwrap();
    }
    let responses = server.drain().unwrap();
    assert_eq!(responses.len(), 5);
    let want = expected_row(&[1, 4], 12);
    for r in &responses {
        assert!(r.error.is_none(), "id {}: {:?}", r.id, r.error);
        assert_eq!(r.row, want, "id {}", r.id);
    }
    let stats = server.stats();
    assert_eq!(stats.lane_bypasses, 1, "exactly one bounded bypass");
    assert_eq!(stats.per_class.interactive.requests, 3);
    assert_eq!(stats.per_class.batch.requests, 2);
    assert_eq!(stats.per_class.interactive.gen_tokens, 15, "3 requests x 5 tokens");
    assert_eq!(stats.per_class.batch.gen_tokens, 10);
    assert_eq!(stats.shed, 0, "no admission pressure in this run");
    common::cleanup("fchaos_lanes");
}
