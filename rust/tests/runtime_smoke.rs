//! End-to-end runtime smoke tests in two tiers.
//!
//! Hermetic tier (always runs, every machine, no artifacts, no XLA):
//! the reference backend executes the same chain — device-resident state
//! through train steps, scalars artifact, fwd + eval artifacts — over a
//! synthetic manifest. Artifact tier (additional, when AOT artifacts
//! exist): the identical assertions against the real `size-xs` artifacts
//! on the engine's default backend.

mod common;

use qadx::coordinator::init_params;
use qadx::runtime::{scalar, Batch, DeviceState, Engine, ModelRuntime};
use qadx::util::rng::Rng;

fn rand_batch(rt: &ModelRuntime, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    let (b, s) = (rt.model.batch, rt.model.seq_len);
    Batch {
        tokens: (0..b * s).map(|_| rng.range(4, rt.model.vocab as i64) as i32).collect(),
        mask: vec![1.0; b * s],
        pixels: None,
        advantage: None,
    }
}

fn assert_sft_chain_decreases_loss(engine: &Engine, model: &str) {
    let rt = ModelRuntime::new(engine, model).unwrap();
    let params = init_params(&rt.model, 0);
    let mut state = DeviceState::from_params(&rt, &params).unwrap();
    let exe = rt.exe("sft_bf16").unwrap();
    let batch = rand_batch(&rt, 1);
    let tokens = rt.upload_tokens(&batch).unwrap();
    let mask = rt.upload_mask(&batch).unwrap();
    let lr = engine.upload_scalar(3e-3).unwrap();

    let mut first = None;
    for _ in 0..20 {
        let out = engine.run_b(&exe, &[&state.buf, &tokens, &mask, &lr]).unwrap();
        state.advance(out);
        let sc = state.scalars().unwrap();
        if first.is_none() {
            first = Some(sc[scalar::LOSS]);
        }
    }
    let sc = state.scalars().unwrap();
    assert_eq!(sc[scalar::STEP], 20.0);
    assert!(sc[scalar::LOSS] < first.unwrap(), "{} !< {:?}", sc[scalar::LOSS], first);
    assert!((sc[scalar::LR] - 3e-3).abs() < 1e-9);
}

fn assert_qad_chain_reduces_kl(engine: &Engine, model: &str) {
    let rt = ModelRuntime::new(engine, model).unwrap();
    let teacher = init_params(&rt.model, 5);
    let mut state = DeviceState::from_params(&rt, &teacher).unwrap();
    let exe = rt.exe("qad_nvfp4").unwrap();
    let batch = rand_batch(&rt, 2);
    let tokens = rt.upload_tokens(&batch).unwrap();
    let mask = rt.upload_mask(&batch).unwrap();
    let lr = engine.upload_scalar(1e-3).unwrap();
    let t_buf = rt.upload_params(&teacher).unwrap();

    let mut kls = Vec::new();
    for _ in 0..15 {
        let out = engine
            .run_b(&exe, &[&state.buf, &t_buf, &tokens, &mask, &lr])
            .unwrap();
        state.advance(out);
        kls.push(state.scalars().unwrap()[scalar::KL]);
    }
    assert!(kls[14] < kls[0], "KL did not fall: {:?}", kls);
    assert!(kls.iter().all(|&k| k >= 0.0));
}

fn assert_fwd_and_eval_metrics(engine: &Engine, model: &str) {
    let rt = ModelRuntime::new(engine, model).unwrap();
    let params = init_params(&rt.model, 0);
    let p_buf = rt.upload_params(&params).unwrap();
    let batch = rand_batch(&rt, 3);
    let tokens = rt.upload_tokens(&batch).unwrap();
    let (b, s, v) = (rt.model.batch, rt.model.seq_len, rt.model.vocab);

    let fwd = rt.exe("fwd_bf16").unwrap();
    let logits_buf = engine.run_b(&fwd, &[&p_buf, &tokens]).unwrap();
    let logits = engine.download_f32(&logits_buf, b * s * v).unwrap();
    assert!(logits.iter().all(|x| x.is_finite()));

    // eval_bf16(params, params, ...) must give exactly KL = 0.
    let mask = rt.upload_mask(&batch).unwrap();
    let ev = rt.exe("eval_bf16").unwrap();
    let out = engine.run_b(&ev, &[&p_buf, &p_buf, &tokens, &mask]).unwrap();
    let m = engine.download_f32(&out, 8).unwrap();
    assert!(m[0].abs() < 1e-5, "KL {m:?}");
    assert!(m[1] > 0.0);

    // eval_nvfp4(params, params, ...) — PTQ gap — must give KL > 0.
    let evq = rt.exe("eval_nvfp4").unwrap();
    let outq = engine.run_b(&evq, &[&p_buf, &p_buf, &tokens, &mask]).unwrap();
    let mq = engine.download_f32(&outq, 8).unwrap();
    assert!(mq[0] > 1e-6, "quantized KL {mq:?}");
}

// --- hermetic tier (reference backend, synthetic manifest) -----------------

#[test]
fn sft_step_chain_decreases_loss() {
    let engine = common::reference_engine("smoke_sft", &[common::small_spec("size-smoke")]);
    assert_sft_chain_decreases_loss(&engine, "size-smoke");
    common::cleanup("smoke_sft");
}

#[test]
fn qad_step_reduces_kl_against_teacher() {
    let engine = common::reference_engine("smoke_qad", &[common::small_spec("size-smoke")]);
    assert_qad_chain_reduces_kl(&engine, "size-smoke");
    common::cleanup("smoke_qad");
}

#[test]
fn fwd_logits_shape_and_eval_metrics() {
    let engine = common::reference_engine("smoke_fwd", &[common::small_spec("size-smoke")]);
    assert_fwd_and_eval_metrics(&engine, "size-smoke");
    common::cleanup("smoke_fwd");
}

#[test]
fn hermetic_chain_works_on_hybrid_blocks() {
    // The reference backend's ssm/moe paths through the same smoke chain.
    let mut spec = common::small_spec("size-hybrid");
    spec.blocks = vec!["ssm".into(), "moe".into(), "attn".into()];
    spec.n_experts = 3;
    let engine = common::reference_engine("smoke_hybrid", &[spec]);
    assert_sft_chain_decreases_loss(&engine, "size-hybrid");
    assert_fwd_and_eval_metrics(&engine, "size-hybrid");
    common::cleanup("smoke_hybrid");
}

#[test]
fn download_element_count_mismatch_is_an_error() {
    // Engine::download_f32_into must reject a wrong caller length instead
    // of trusting it — both via the buffer's known shape (pre-transfer)
    // and the backend's element count (post-transfer).
    let engine = common::reference_engine("smoke_dl", &[common::small_spec("size-smoke")]);
    let buf = engine.upload_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
    let mut out = Vec::new();
    let err = engine.download_f32_into(&buf, 7, &mut out).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains('7') && msg.contains('6'), "unhelpful error: {msg}");
    assert!(out.is_empty(), "mismatched download must not write output");
    engine.download_f32_into(&buf, 6, &mut out).unwrap();
    assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    // the whole-buffer convenience path agrees
    assert!(engine.download_f32(&buf, 5).is_err());
    common::cleanup("smoke_dl");
}

// --- artifact tier (real AOT artifacts, default backend) -------------------

#[test]
fn sft_step_chain_decreases_loss_artifact_tier() {
    let Some(dir) = common::real_artifacts_dir() else {
        common::artifact_tier_disabled("sft_step_chain");
        return;
    };
    let engine = Engine::new(&dir).expect("engine");
    assert_sft_chain_decreases_loss(&engine, "size-xs");
}

#[test]
fn qad_step_reduces_kl_against_teacher_artifact_tier() {
    let Some(dir) = common::real_artifacts_dir() else {
        common::artifact_tier_disabled("qad_step_chain");
        return;
    };
    let engine = Engine::new(&dir).expect("engine");
    assert_qad_chain_reduces_kl(&engine, "size-xs");
}

#[test]
fn fwd_logits_shape_and_eval_metrics_artifact_tier() {
    let Some(dir) = common::real_artifacts_dir() else {
        common::artifact_tier_disabled("fwd_logits_eval");
        return;
    };
    let engine = Engine::new(&dir).expect("engine");
    assert_fwd_and_eval_metrics(&engine, "size-xs");
}
