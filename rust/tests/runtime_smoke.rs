//! End-to-end runtime smoke test: load AOT artifacts, chain train steps
//! with a device-resident state vector, verify metrics and convergence.
//! Requires `make artifacts` (skipped with a clear message otherwise).

use qadx::coordinator::init_params;
use qadx::runtime::{scalar, Batch, DeviceState, Engine, ModelRuntime};
use qadx::util::rng::Rng;
use std::path::Path;

fn engine() -> Option<Engine> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::new(&dir).expect("engine"))
}

fn rand_batch(rt: &ModelRuntime, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    let (b, s) = (rt.model.batch, rt.model.seq_len);
    Batch {
        tokens: (0..b * s).map(|_| rng.range(4, rt.model.vocab as i64) as i32).collect(),
        mask: vec![1.0; b * s],
        pixels: None,
        advantage: None,
    }
}

#[test]
fn sft_step_chain_decreases_loss() {
    let Some(engine) = engine() else { return };
    let rt = ModelRuntime::new(&engine, "size-xs").unwrap();
    let params = init_params(&rt.model, 0);
    let mut state = DeviceState::from_params(&rt, &params).unwrap();
    let exe = rt.exe("sft_bf16").unwrap();
    let batch = rand_batch(&rt, 1);
    let tokens = rt.upload_tokens(&batch).unwrap();
    let mask = rt.upload_mask(&batch).unwrap();
    let lr = engine.upload_scalar(3e-3).unwrap();

    let mut first = None;
    for _ in 0..20 {
        let out = engine.run_b(&exe, &[&state.buf, &tokens, &mask, &lr]).unwrap();
        state.advance(out);
        let sc = state.scalars().unwrap();
        if first.is_none() {
            first = Some(sc[scalar::LOSS]);
        }
    }
    let sc = state.scalars().unwrap();
    assert_eq!(sc[scalar::STEP], 20.0);
    assert!(sc[scalar::LOSS] < first.unwrap(), "{} !< {:?}", sc[scalar::LOSS], first);
    assert!((sc[scalar::LR] - 3e-3).abs() < 1e-9);
}

#[test]
fn qad_step_reduces_kl_against_teacher() {
    let Some(engine) = engine() else { return };
    let rt = ModelRuntime::new(&engine, "size-xs").unwrap();
    let teacher = init_params(&rt.model, 5);
    let mut state = DeviceState::from_params(&rt, &teacher).unwrap();
    let exe = rt.exe("qad_nvfp4").unwrap();
    let batch = rand_batch(&rt, 2);
    let tokens = rt.upload_tokens(&batch).unwrap();
    let mask = rt.upload_mask(&batch).unwrap();
    let lr = engine.upload_scalar(1e-3).unwrap();
    let t_buf = rt.upload_params(&teacher).unwrap();

    let mut kls = Vec::new();
    for _ in 0..15 {
        let out = engine
            .run_b(&exe, &[&state.buf, &t_buf, &tokens, &mask, &lr])
            .unwrap();
        state.advance(out);
        kls.push(state.scalars().unwrap()[scalar::KL]);
    }
    assert!(kls[14] < kls[0], "KL did not fall: {:?}", kls);
    assert!(kls.iter().all(|&k| k >= 0.0));
}

#[test]
fn fwd_logits_shape_and_eval_metrics() {
    let Some(engine) = engine() else { return };
    let rt = ModelRuntime::new(&engine, "size-xs").unwrap();
    let params = init_params(&rt.model, 0);
    let p_buf = rt.upload_params(&params).unwrap();
    let batch = rand_batch(&rt, 3);
    let tokens = rt.upload_tokens(&batch).unwrap();
    let (b, s, v) = (rt.model.batch, rt.model.seq_len, rt.model.vocab);

    let fwd = rt.exe("fwd_bf16").unwrap();
    let logits_buf = engine.run_b(&fwd, &[&p_buf, &tokens]).unwrap();
    let logits = engine.download_f32(&logits_buf, b * s * v).unwrap();
    assert!(logits.iter().all(|x| x.is_finite()));

    // eval_bf16(params, params, ...) must give exactly KL = 0.
    let mask = rt.upload_mask(&batch).unwrap();
    let ev = rt.exe("eval_bf16").unwrap();
    let out = engine.run_b(&ev, &[&p_buf, &p_buf, &tokens, &mask]).unwrap();
    let m = engine.download_f32(&out, 8).unwrap();
    assert!(m[0].abs() < 1e-5, "KL {m:?}");
    assert!(m[1] > 0.0);

    // eval_nvfp4(params, params, ...) — PTQ gap — must give KL > 0.
    let evq = rt.exe("eval_nvfp4").unwrap();
    let outq = engine.run_b(&evq, &[&p_buf, &p_buf, &tokens, &mask]).unwrap();
    let mq = engine.download_f32(&outq, 8).unwrap();
    assert!(mq[0] > 1e-6, "quantized KL {mq:?}");
}
