//! Determinism contract of the parallel compute core: the reference
//! backend must produce bit-identical results at every thread count —
//! full QAD train-step chains (packed state vector) and decode (sampled
//! token rows) compared between 1 and 4 workers. The model is sized so
//! its GEMMs cross the pool's parallel-work threshold, i.e. the
//! multi-threaded path really runs at 4 workers (hermetic: no artifacts,
//! no XLA).

mod common;

use qadx::coordinator::init_params;
use qadx::eval::{DecodeMode, SampleCfg, Sampler};
use qadx::runtime::{scalar, Batch, DeviceState, ModelRuntime, SynthSpec};
use qadx::util::pool;
use qadx::util::rng::Rng;

/// Big enough that every GEMM clears PAR_MIN_WORK (rows·d·vocab ≈ 1M),
/// with all three block kinds so the ssm/moe backprops run under the
/// parallel partition too.
fn threaded_spec(name: &str) -> SynthSpec {
    let mut spec = SynthSpec::small(name);
    spec.d_model = 64;
    spec.n_heads = 4;
    spec.d_ff = 128;
    spec.vocab = 256;
    spec.seq_len = 16;
    spec.batch = 4;
    spec.blocks = vec!["attn".into(), "ssm".into(), "moe".into()];
    spec.n_experts = 2;
    spec
}

fn rand_batch(rt: &ModelRuntime, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    let (b, s) = (rt.model.batch, rt.model.seq_len);
    Batch {
        tokens: (0..b * s).map(|_| rng.range(4, rt.model.vocab as i64) as i32).collect(),
        mask: vec![1.0; b * s],
        pixels: None,
        advantage: None,
    }
}

/// Three QAD (KL-distill) steps on the reference backend; returns the
/// full packed state vector after the chain.
fn qad_chain_state(tag: &str, threads: usize) -> Vec<f32> {
    pool::with_threads(threads, || {
        let engine = common::reference_engine(tag, &[threaded_spec("thr-sim")]);
        let rt = ModelRuntime::new(&engine, "thr-sim").unwrap();
        let teacher = init_params(&rt.model, 7);
        let student = init_params(&rt.model, 8);
        let mut state = DeviceState::from_params(&rt, &student).unwrap();
        let exe = rt.exe("qad_nvfp4").unwrap();
        let batch = rand_batch(&rt, 3);
        let tokens = rt.upload_tokens(&batch).unwrap();
        let mask = rt.upload_mask(&batch).unwrap();
        let t_buf = rt.upload_params(&teacher).unwrap();
        let lr = engine.upload_scalar(1e-3).unwrap();
        for _ in 0..3 {
            let out = engine.run_b(&exe, &[&state.buf, &t_buf, &tokens, &mask, &lr]).unwrap();
            state.advance(out);
        }
        let sc = state.scalars().unwrap();
        assert_eq!(sc[scalar::STEP], 3.0);
        state.full().unwrap()
    })
}

#[test]
fn qad_train_chain_bit_identical_across_thread_counts() {
    let one = qad_chain_state("thr_chain1", 1);
    let four = qad_chain_state("thr_chain4", 4);
    assert_eq!(one.len(), four.len());
    for (i, (a, b)) in one.iter().zip(&four).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "packed state diverged at [{i}]: {a} vs {b}");
    }
    common::cleanup("thr_chain1");
    common::cleanup("thr_chain4");
}

/// Decode a fixed prompt set; returns the generated token rows.
fn decode_rows(tag: &str, threads: usize, fwd_key: &str, mode: DecodeMode) -> Vec<Vec<i32>> {
    pool::with_threads(threads, || {
        let engine = common::reference_engine(tag, &[threaded_spec("thr-sim")]);
        let rt = ModelRuntime::new(&engine, "thr-sim").unwrap();
        let params = init_params(&rt.model, 11);
        let cfg = SampleCfg { temperature: 0.8, top_p: 0.9, max_new: 8, seed: 5 };
        let mut sampler = Sampler::new(&rt, fwd_key, cfg).unwrap();
        sampler.set_decode_mode(mode);
        let weights = engine.upload_f32(&params, &[params.len()]).unwrap();
        let prompts: Vec<Vec<i32>> =
            (0..rt.model.batch).map(|i| vec![4 + i as i32, 9, 6]).collect();
        sampler.generate(&engine, &weights, &prompts, None).unwrap()
    })
}

#[test]
fn decode_tokens_identical_across_thread_counts() {
    // quantized decode stays deterministic under threading on every
    // path: stateful prefill/step, the frontier gather, and the full
    // forward (and Step == Full by the decode-equivalence contract, so
    // all four row sets below must in fact agree per key)
    for fwd_key in ["fwd_nvfp4", "fwd_bf16"] {
        let mut per_mode = Vec::new();
        for mode in [DecodeMode::Step, DecodeMode::Full] {
            let one = decode_rows("thr_dec1", 1, fwd_key, mode);
            let four = decode_rows("thr_dec4", 4, fwd_key, mode);
            assert_eq!(one, four, "decode rows diverged for {fwd_key} ({mode})");
            common::cleanup("thr_dec1");
            common::cleanup("thr_dec4");
            per_mode.push(one);
        }
        assert_eq!(per_mode[0], per_mode[1], "step vs full diverged for {fwd_key}");
    }
}

#[test]
fn ssm_scan_and_moe_lanes_bit_identical_when_scan_itself_parallelizes() {
    // The lane-parallel ssm scan region's work estimate is rows·d·4:
    // batch 8 × seq 64 × d 64 gives 131072 ≥ PAR_MIN_WORK, so the scan
    // (and the moe gated combine at rows·d·2 = 65536) genuinely
    // partitions across workers at 4 threads — not the inline fallback.
    use qadx::runtime::refmodel::{self, RefCfg};
    let mut spec = SynthSpec::small("scan-sim");
    spec.d_model = 64;
    spec.n_heads = 4;
    spec.d_ff = 128;
    spec.vocab = 128;
    spec.seq_len = 64;
    spec.batch = 8;
    spec.blocks = vec!["ssm".into(), "moe".into()];
    spec.n_experts = 2;
    let entry = spec.entry();
    let cfg = RefCfg::for_key_format(&entry, "nvfp4").unwrap();
    let params = init_params(&entry, 23);
    let mut rng = Rng::new(29);
    let tokens: Vec<i32> =
        (0..entry.batch * entry.seq_len).map(|_| rng.range(4, entry.vocab as i64) as i32).collect();
    let run = |threads: usize| {
        pool::with_threads(threads, || {
            refmodel::fwd_logits(&cfg, &params, &tokens, entry.batch, entry.seq_len, None)
                .unwrap()
        })
    };
    let one = run(1);
    let four = run(4);
    for (i, (a, b)) in one.iter().zip(&four).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "logits[{i}]: {a} vs {b}");
    }
}

#[test]
fn eval_metrics_bit_identical_across_thread_counts() {
    let run = |tag: &str, threads: usize| {
        pool::with_threads(threads, || {
            let engine = common::reference_engine(tag, &[threaded_spec("thr-sim")]);
            let rt = ModelRuntime::new(&engine, "thr-sim").unwrap();
            let params = init_params(&rt.model, 13);
            let exe = rt.exe("eval_nvfp4").unwrap();
            let batch = rand_batch(&rt, 17);
            let tokens = rt.upload_tokens(&batch).unwrap();
            let mask = rt.upload_mask(&batch).unwrap();
            let p_buf = rt.upload_params(&params).unwrap();
            let out = engine.run_b(&exe, &[&p_buf, &p_buf, &tokens, &mask]).unwrap();
            engine.download_f32(&out, 8).unwrap()
        })
    };
    let one = run("thr_ev1", 1);
    let four = run("thr_ev4", 4);
    for (i, (a, b)) in one.iter().zip(&four).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "eval metric [{i}]: {a} vs {b}");
    }
    common::cleanup("thr_ev1");
    common::cleanup("thr_ev4");
}
