//! Schedule-stress determinism sweep: the hermetic QAD train step, the
//! stateful stepped decoder, and the continuous-batching serve scheduler
//! must be bit-identical at every pool width QADX_THREADS ∈ {1,2,3,4} —
//! not just the 1-vs-4 endpoints the threading suite pins. Serve runs
//! compare full responses (ids, token rows, gen counts) *and* the
//! telemetry JSONL stream on its deterministic projection (every `*_ms`
//! timing field stripped; field order is stable because `Json::Obj` is a
//! BTreeMap). Entirely hermetic: reference backend, synthetic manifests.

mod common;

use qadx::api::{DecodeMode, ServeCfg, ServeWeights};
use qadx::coordinator::init_params;
use qadx::eval::{SampleCfg, Sampler};
use qadx::runtime::refmodel::{self, RefCfg};
use qadx::runtime::{scalar, Batch, DeviceState, ModelRuntime, SynthSpec};
use qadx::util::json::Json;
use qadx::util::pool;
use qadx::util::rng::Rng;

const SWEEP: [usize; 4] = [1, 2, 3, 4];

/// Big enough that GEMMs cross the pool's parallel-work threshold, with
/// all three block kinds, so every thread count in the sweep genuinely
/// partitions work differently.
fn stress_spec(name: &str) -> SynthSpec {
    let mut spec = SynthSpec::small(name);
    spec.d_model = 64;
    spec.n_heads = 4;
    spec.d_ff = 128;
    spec.vocab = 256;
    spec.seq_len = 16;
    spec.batch = 4;
    spec.blocks = vec!["attn".into(), "ssm".into(), "moe".into()];
    spec.n_experts = 2;
    spec
}

fn rand_batch(rt: &ModelRuntime, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    let (b, s) = (rt.model.batch, rt.model.seq_len);
    Batch {
        tokens: (0..b * s).map(|_| rng.range(4, rt.model.vocab as i64) as i32).collect(),
        mask: vec![1.0; b * s],
        pixels: None,
        advantage: None,
    }
}

fn assert_bits_eq(what: &str, threads: usize, base: &[f32], got: &[f32]) {
    assert_eq!(base.len(), got.len(), "{what}: length diverged at {threads} threads");
    for (i, (a, b)) in base.iter().zip(got).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{what}: [{i}] diverged at {threads} threads: {a} vs {b}"
        );
    }
}

#[test]
fn qad_step_chain_bit_identical_across_thread_sweep() {
    let chain = |tag: &str, threads: usize| -> Vec<f32> {
        pool::with_threads(threads, || {
            let engine = common::reference_engine(tag, &[stress_spec("stress-sim")]);
            let rt = ModelRuntime::new(&engine, "stress-sim").unwrap();
            let teacher = init_params(&rt.model, 7);
            let student = init_params(&rt.model, 8);
            let mut state = DeviceState::from_params(&rt, &student).unwrap();
            let exe = rt.exe("qad_nvfp4").unwrap();
            let batch = rand_batch(&rt, 3);
            let tokens = rt.upload_tokens(&batch).unwrap();
            let mask = rt.upload_mask(&batch).unwrap();
            let t_buf = rt.upload_params(&teacher).unwrap();
            let lr = engine.upload_scalar(1e-3).unwrap();
            for _ in 0..3 {
                let out = engine.run_b(&exe, &[&state.buf, &t_buf, &tokens, &mask, &lr]).unwrap();
                state.advance(out);
            }
            let sc = state.scalars().unwrap();
            assert_eq!(sc[scalar::STEP], 3.0);
            state.full().unwrap()
        })
    };
    let base = chain("sstress_qad_1", 1);
    for t in &SWEEP[1..] {
        let tag = format!("sstress_qad_{t}");
        let got = chain(&tag, *t);
        assert_bits_eq("qad packed state", *t, &base, &got);
        common::cleanup(&tag);
    }
    common::cleanup("sstress_qad_1");
}

#[test]
fn forward_logits_bit_identical_across_thread_sweep() {
    let spec = stress_spec("stress-sim");
    let entry = spec.entry();
    let cfg = RefCfg::for_key_format(&entry, "nvfp4").unwrap();
    let params = init_params(&entry, 23);
    let mut rng = Rng::new(29);
    let tokens: Vec<i32> = (0..entry.batch * entry.seq_len)
        .map(|_| rng.range(4, entry.vocab as i64) as i32)
        .collect();
    let run = |threads: usize| {
        pool::with_threads(threads, || {
            refmodel::fwd_logits(&cfg, &params, &tokens, entry.batch, entry.seq_len, None).unwrap()
        })
    };
    let base = run(1);
    for t in &SWEEP[1..] {
        assert_bits_eq("fwd logits", *t, &base, &run(*t));
    }
}

#[test]
fn stepped_decode_rows_identical_across_thread_sweep() {
    let rows = |tag: &str, threads: usize| -> Vec<Vec<i32>> {
        pool::with_threads(threads, || {
            let engine = common::reference_engine(tag, &[stress_spec("stress-sim")]);
            let rt = ModelRuntime::new(&engine, "stress-sim").unwrap();
            let params = init_params(&rt.model, 11);
            let cfg = SampleCfg { temperature: 0.8, top_p: 0.9, max_new: 8, seed: 5 };
            let mut sampler = Sampler::new(&rt, "fwd_nvfp4", cfg).unwrap();
            sampler.set_decode_mode(DecodeMode::Step);
            let weights = engine.upload_f32(&params, &[params.len()]).unwrap();
            let prompts: Vec<Vec<i32>> =
                (0..rt.model.batch).map(|i| vec![4 + i as i32, 9, 6]).collect();
            sampler.generate(&engine, &weights, &prompts, None).unwrap()
        })
    };
    let base = rows("sstress_dec_1", 1);
    for t in &SWEEP[1..] {
        let tag = format!("sstress_dec_{t}");
        assert_eq!(base, rows(&tag, *t), "stepped decode diverged at {t} threads");
        common::cleanup(&tag);
    }
    common::cleanup("sstress_dec_1");
}

/// One serve run: continuous scheduler, 2 slots, 6 requests submitted in
/// two waves with polls in between (so slots free mid-generation and
/// late requests admit mid-gen), telemetry to a JSONL file. Returns the
/// completed responses (sorted by id) and the telemetry stream projected
/// onto its deterministic fields.
type ServeRows = Vec<(u64, Vec<i32>, usize, Option<String>)>;

fn serve_run(tag: &str, threads: usize) -> (ServeRows, Vec<String>) {
    pool::with_threads(threads, || {
        let session = common::reference_session(tag, &[stress_spec("stress-sim")]);
        let ms = session.model("stress-sim").unwrap();
        let tel_path = common::tmp_runs(tag).join("serve_telemetry.jsonl");
        let cfg = ServeCfg {
            sample: SampleCfg { temperature: 0.7, top_p: 0.9, max_new: 6, seed: 9 },
            weights: ServeWeights::Random { seed: 21 },
            decode: DecodeMode::Step, // require the continuous scheduler
            max_slots: 2,
            telemetry: Some(tel_path.clone()),
            ..ServeCfg::default()
        };
        let mut server = ms.server("fwd_nvfp4", &cfg).unwrap();
        assert!(server.continuous(), "reference backend must serve continuously");
        for i in 0..3u64 {
            server.submit(vec![1, 4 + i as i32, 3]).unwrap();
        }
        server.poll().unwrap();
        server.poll().unwrap();
        for i in 3..6u64 {
            server.submit(vec![1, 4 + i as i32, 3, 5]).unwrap();
        }
        let mut responses = server.drain().unwrap();
        assert_eq!(server.stats().degraded, 0, "no request may degrade in this sweep");
        responses.sort_by_key(|r| r.id);
        let rows: Vec<(u64, Vec<i32>, usize, Option<String>)> = responses
            .into_iter()
            .map(|r| (r.id, r.row, r.gen_tokens, r.error))
            .collect();

        let raw = std::fs::read_to_string(&tel_path).unwrap();
        let projected: Vec<String> = raw
            .lines()
            .map(|line| {
                let ev = Json::parse(line).unwrap();
                let obj = ev.as_obj().unwrap();
                // wall-clock timing differs run to run; everything else
                // (event kinds, ids, token counts, slots, fwd key, order
                // of events) must be identical at every thread count
                let kept: Vec<(&str, Json)> = obj
                    .iter()
                    .filter(|(k, _)| !k.ends_with("_ms"))
                    .map(|(k, v)| (k.as_str(), v.clone()))
                    .collect();
                Json::obj(kept).to_string()
            })
            .collect();
        (rows, projected)
    })
}

#[test]
fn continuous_serve_responses_and_telemetry_identical_across_thread_sweep() {
    let (base_rows, base_tel) = serve_run("sstress_srv_1", 1);
    assert_eq!(base_rows.len(), 6, "all submitted requests complete");
    assert!(base_rows.iter().all(|(_, _, _, e)| e.is_none()));
    assert!(!base_tel.is_empty(), "telemetry stream captured");
    for t in &SWEEP[1..] {
        let tag = format!("sstress_srv_{t}");
        let (rows, tel) = serve_run(&tag, *t);
        assert_eq!(base_rows, rows, "serve responses diverged at {t} threads");
        assert_eq!(base_tel, tel, "telemetry projection diverged at {t} threads");
        common::cleanup(&tag);
    }
    common::cleanup("sstress_srv_1");
}
