//! Property-based tests over coordinator/data/quant invariants.
//!
//! `proptest` is not in the offline crates cache, so this uses the same
//! structure by hand: seeded random-input generators sweeping hundreds of
//! cases per invariant (no shrinking — failing seeds are printed so a case
//! can be replayed directly).

use qadx::coordinator::{merge, Checkpoint, LrSchedule, TrainCfg};
use qadx::data::{
    sources::decode_response, tasks, tokenizer as tok, BatchFactory, BatchShape, SourceKind,
    SourceSpec, TEXT_SUITES, VISION_SUITES,
};
use qadx::eval::{sample_token, SampleCfg};
use qadx::quant::fp::{e2m1_round, e4m3_round};
use qadx::quant::nvfp4::{self, Nvfp4Tensor};
use qadx::util::json::Json;
use qadx::util::rng::Rng;
use qadx::util::{percentile, StatsWindow};

fn cases(n: usize) -> impl Iterator<Item = u64> {
    (0..n as u64).map(|i| 0xBEEF ^ i.wrapping_mul(0x9E3779B97F4A7C15))
}

// ------------------------------------------------------------------- quant

#[test]
fn prop_fake_quant_idempotent() {
    for seed in cases(60) {
        let mut rng = Rng::new(seed);
        let rows = 1 + rng.below(24);
        let cols = 16 * (1 + rng.below(8));
        let scale = [1e-4f32, 1.0, 300.0][rng.below(3)];
        let x: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32 * scale).collect();
        let q1 = nvfp4::fake_quant(&x, rows, cols);
        let q2 = nvfp4::fake_quant(&q1, rows, cols);
        for (i, (a, b)) in q1.iter().zip(&q2).enumerate() {
            assert!(
                (a - b).abs() <= a.abs() * 1e-6 + 1e-12,
                "seed {seed}: idempotency broke at {i}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn prop_quant_error_bounded() {
    // NVFP4 worst-case relative elementwise error within a block is bounded
    // by the E2M1 grid spacing (~1/3 relative) once scales are sane.
    for seed in cases(40) {
        let mut rng = Rng::new(seed);
        let cols = 16 * (1 + rng.below(6));
        let x: Vec<f32> = (0..cols).map(|_| rng.normal() as f32).collect();
        let q = nvfp4::fake_quant(&x, 1, cols);
        let rel = nvfp4::rel_error(&x, &q);
        assert!(rel < 0.35, "seed {seed}: rel error {rel}");
    }
}

#[test]
fn prop_codes_round_trip_through_packing() {
    for seed in cases(40) {
        let mut rng = Rng::new(seed);
        let rows = 1 + rng.below(8);
        let cols = 16 * (1 + rng.below(4));
        let x: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32 * 2.0).collect();
        let t = Nvfp4Tensor::quantize(&x, rows, cols, None);
        // decode each packed code and re-encode: must be a fixed point
        for i in 0..rows * cols {
            let code = t.code_at(i);
            assert!(code & 0xf0 == 0, "nibble overflow");
            let v = qadx::quant::fp::e2m1_decode(code);
            let c2 = qadx::quant::fp::e2m1_encode(v);
            assert_eq!(qadx::quant::fp::e2m1_decode(c2), v, "seed {seed} idx {i}");
        }
    }
}

#[test]
fn prop_scalar_round_monotone() {
    for seed in cases(20) {
        let mut rng = Rng::new(seed);
        let mut xs: Vec<f32> = (0..200).map(|_| rng.normal() as f32 * 200.0).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut prev4 = f32::NEG_INFINITY;
        let mut prev2 = f32::NEG_INFINITY;
        for x in xs {
            let a = e4m3_round(x);
            let b = e2m1_round(x);
            assert!(a >= prev4, "e4m3 monotonicity at {x}");
            assert!(b >= prev2, "e2m1 monotonicity at {x}");
            prev4 = a;
            prev2 = b;
        }
    }
}

// -------------------------------------------------------------------- data

#[test]
fn prop_batches_well_formed_across_sources_and_shapes() {
    for seed in cases(40) {
        let mut rng = Rng::new(seed);
        let vision = rng.bool(0.3);
        let shape = BatchShape {
            batch: [4, 8, 16][rng.below(3)],
            seq_len: [40, 48, 64][rng.below(3)],
            vision,
            grid: 4,
            patch: 16,
            vocab: 64,
        };
        let suites = if vision { VISION_SUITES } else { TEXT_SUITES };
        let kind = match rng.below(2) {
            0 => SourceKind::Sft { p_correct: rng.f64() },
            _ => SourceKind::RandomTokens,
        };
        let spec = SourceSpec { kind, suites: suites.to_vec(), weight: 1.0 };
        let mut f = BatchFactory::new(shape, vec![spec], seed);
        let b = f.next_batch(None).expect("batch");
        assert_eq!(b.tokens.len(), shape.batch * shape.seq_len, "seed {seed}");
        assert_eq!(b.mask.len(), shape.batch * shape.seq_len);
        assert_eq!(b.pixels.is_some(), vision);
        // every token id in vocab, every mask bit 0/1, some mask per row
        assert!(b.tokens.iter().all(|&t| (0..64).contains(&t)));
        assert!(b.mask.iter().all(|&m| m == 0.0 || m == 1.0));
        for r in 0..shape.batch {
            let row = &b.mask[r * shape.seq_len..(r + 1) * shape.seq_len];
            assert!(row.iter().sum::<f32>() >= 1.0, "seed {seed} row {r} empty mask");
        }
    }
}

#[test]
fn prop_factory_deterministic_per_seed() {
    let shape = BatchShape { batch: 8, seq_len: 40, vision: false, grid: 4, patch: 16, vocab: 64 };
    for seed in cases(20) {
        let spec = SourceSpec::sft(TEXT_SUITES);
        let mut a = BatchFactory::new(shape, vec![spec.clone()], seed);
        let mut b = BatchFactory::new(shape, vec![spec], seed);
        for _ in 0..3 {
            let ba = a.next_batch(None).unwrap();
            let bb = b.next_batch(None).unwrap();
            assert_eq!(ba.tokens, bb.tokens, "seed {seed}");
            assert_eq!(ba.mask, bb.mask);
        }
    }
}

#[test]
fn prop_task_rows_decode_to_answer() {
    for seed in cases(60) {
        let mut rng = Rng::new(seed);
        let suite = *rng.choice(TEXT_SUITES);
        let s = tasks::generate(suite, &mut rng, 4, 16);
        let (tokens, _mask) = tasks::build_row(&s, &s.answer, 40);
        let prompt = tasks::prompt_tokens(&s, 40);
        let resp = decode_response(&tokens, &prompt);
        assert_eq!(resp.trim(), s.answer, "seed {seed} suite {suite:?}");
    }
}

#[test]
fn prop_tokenizer_round_trips_task_strings() {
    for seed in cases(100) {
        let mut rng = Rng::new(seed);
        let suite = *rng.choice(TEXT_SUITES);
        let s = tasks::generate(suite, &mut rng, 4, 16);
        let text = format!("{}{}", s.prompt, s.answer);
        assert_eq!(tok::decode(&tok::encode(&text)), text, "seed {seed}");
    }
}

// -------------------------------------------------------------- coordinator

#[test]
fn prop_lr_schedule_bounded_and_warmup_monotone() {
    for seed in cases(40) {
        let mut rng = Rng::new(seed);
        let steps = 50 + rng.below(500);
        let warmup = rng.below(steps / 2);
        let lr = 10f64.powf(-(2.0 + rng.f64() * 4.0));
        let cfg = TrainCfg {
            steps,
            lr,
            schedule: LrSchedule::CosineWarmup { warmup, floor: 0.1 },
            ..TrainCfg::default()
        };
        let mut prev = 0.0;
        for s in 0..steps {
            let v = cfg.lr_at(s);
            assert!(v > 0.0 && v <= lr * (1.0 + 1e-9), "seed {seed} step {s}: {v}");
            if s < warmup {
                assert!(v >= prev, "warmup must be nondecreasing");
            }
            prev = v;
        }
        // tail reaches the floor region
        assert!(cfg.lr_at(steps - 1) <= lr * 0.2 + 1e-12);
    }
}

#[test]
fn prop_topk_checkpoint_selection() {
    for seed in cases(40) {
        let mut rng = Rng::new(seed);
        let n = 1 + rng.below(20);
        let mut log = qadx::coordinator::TrainLog::default();
        for i in 0..n {
            log.checkpoints.push(Checkpoint {
                step: i,
                val_loss: rng.f64() * 10.0,
                params: vec![],
            });
        }
        let top = log.top_checkpoints();
        assert_eq!(top.len(), n);
        for w in top.windows(2) {
            assert!(w[0].val_loss <= w[1].val_loss, "seed {seed}: not sorted");
        }
    }
}

#[test]
fn prop_merge_lerp_between_endpoints() {
    for seed in cases(40) {
        let mut rng = Rng::new(seed);
        let n = 1 + rng.below(100);
        let a: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let alpha = rng.f32();
        let m = merge::lerp(&a, &b, alpha).unwrap();
        for i in 0..n {
            let lo = a[i].min(b[i]) - 1e-5;
            let hi = a[i].max(b[i]) + 1e-5;
            assert!(m[i] >= lo && m[i] <= hi, "seed {seed} idx {i}");
        }
    }
}

// ----------------------------------------------------------------- sampling

/// Full-sort top-p oracle mirroring the seed semantics: sort candidates by
/// descending probability, keep the minimal prefix whose cumulative mass
/// reaches p·z, walk it highest-first with one uniform draw.
fn sample_token_oracle(cfg: &SampleCfg, rng: &mut Rng, logits: &[f32]) -> i32 {
    if cfg.temperature <= 0.0 {
        return argmax_oracle(logits);
    }
    let inv_t = 1.0 / cfg.temperature;
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut probs: Vec<(f64, u32)> = Vec::with_capacity(logits.len());
    let mut z = 0f64;
    for (i, &l) in logits.iter().enumerate() {
        let p = (((l - mx) * inv_t) as f64).exp();
        z += p;
        probs.push((p, i as u32));
    }
    if z.is_nan() || z <= 0.0 {
        return argmax_oracle(logits);
    }
    if cfg.top_p >= 1.0 {
        let mut x = rng.f64() * z;
        for &(p, i) in probs.iter() {
            x -= p;
            if x <= 0.0 {
                return i as i32;
            }
        }
        return probs.last().map(|&(_, i)| i as i32).unwrap_or(0);
    }
    let mut sorted = probs;
    sorted.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let target = cfg.top_p as f64 * z;
    let mut cum = 0f64;
    let mut k = 0usize;
    while k < sorted.len() {
        cum += sorted[k].0;
        k += 1;
        if cum >= target {
            break;
        }
    }
    let mut x = rng.f64() * cum;
    for &(p, i) in sorted[..k].iter() {
        x -= p;
        if x <= 0.0 {
            return i as i32;
        }
    }
    sorted[k - 1].1 as i32
}

fn argmax_oracle(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &l) in logits.iter().enumerate() {
        if l > logits[best] {
            best = i;
        }
    }
    best as i32
}

/// Scaled probabilities exactly as both implementations compute them.
fn scaled_probs(cfg: &SampleCfg, logits: &[f32]) -> Vec<f64> {
    let inv_t = 1.0 / cfg.temperature;
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    logits.iter().map(|&l| (((l - mx) * inv_t) as f64).exp()).collect()
}

#[test]
fn prop_top_p_heap_matches_full_sort_oracle_on_distinct_probs() {
    // With all probabilities distinct, the heap's partial selection visits
    // candidates in exactly the oracle's sorted order, so the kept set,
    // cumulative mass, and single rng draw must coincide draw-for-draw.
    let mut hits = 0usize;
    for seed in cases(120) {
        let mut rng = Rng::new(seed);
        let n = 2 + rng.below(48);
        let temperature = 0.3 + rng.f32() * 1.5;
        let top_p = 0.05 + rng.f32() * 0.9;
        let logits: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 3.0).collect();
        let cfg = SampleCfg { temperature, top_p, max_new: 1, seed };
        let probs = scaled_probs(&cfg, &logits);
        let mut sorted = probs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if sorted.windows(2).any(|w| w[0] == w[1]) {
            continue; // tie — covered by the membership property below
        }
        for draw in 0..8u64 {
            let mut r1 = Rng::new(seed ^ (draw << 32));
            let mut r2 = Rng::new(seed ^ (draw << 32));
            let a = sample_token(&cfg, &mut r1, &logits);
            let b = sample_token_oracle(&cfg, &mut r2, &logits);
            assert_eq!(a, b, "seed {seed} draw {draw}: heap {a} vs oracle {b}");
            hits += 1;
        }
    }
    assert!(hits > 500, "too few distinct-prob cases exercised ({hits})");
}

#[test]
fn prop_top_p_ties_never_escape_the_nucleus_closure() {
    // Adversarial ties: logits drawn from a tiny value set so many
    // candidates share identical probabilities, including at the nucleus
    // boundary. Whatever the heap's tie order, the sampled token's
    // probability must be >= the k-th largest (the tie-closed nucleus).
    for seed in cases(80) {
        let mut rng = Rng::new(seed);
        let n = 4 + rng.below(28);
        let vals = [0.0f32, 1.0, 2.0];
        let logits: Vec<f32> = (0..n).map(|_| *rng.choice(&vals)).collect();
        let top_p = [0.3f32, 0.5, 0.7, 0.9][rng.below(4)];
        let cfg = SampleCfg { temperature: 1.0, top_p, max_new: 1, seed };
        let probs = scaled_probs(&cfg, &logits);
        let z: f64 = probs.iter().sum();
        let mut sorted = probs.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let target = top_p as f64 * z;
        let mut cum = 0f64;
        let mut k = 0usize;
        while k < sorted.len() {
            cum += sorted[k];
            k += 1;
            if cum >= target {
                break;
            }
        }
        let min_kept = sorted[k - 1];
        for draw in 0..10u64 {
            let mut r = Rng::new(seed ^ (draw << 24) ^ 0xA5);
            let t = sample_token(&cfg, &mut r, &logits) as usize;
            assert!(
                probs[t] >= min_kept,
                "seed {seed}: sampled prob {} below nucleus floor {min_kept} (p {top_p})",
                probs[t]
            );
        }
    }
}

#[test]
fn prop_top_p_edge_values() {
    for seed in cases(40) {
        let mut rng = Rng::new(seed);
        let n = 3 + rng.below(20);
        let logits: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 2.0).collect();
        // p = 1.0: no nucleus cut — one cumulative walk in index order,
        // identical to the oracle's p>=1 branch draw-for-draw.
        let cfg1 = SampleCfg { temperature: 0.9, top_p: 1.0, max_new: 1, seed };
        let mut r1 = Rng::new(seed ^ 1);
        let mut r2 = Rng::new(seed ^ 1);
        assert_eq!(
            sample_token(&cfg1, &mut r1, &logits),
            sample_token_oracle(&cfg1, &mut r2, &logits),
            "seed {seed} (p=1.0)"
        );
        // p = 0.0: nucleus degenerates to a single maximal-probability
        // token.
        let cfg0 = SampleCfg { temperature: 0.9, top_p: 0.0, max_new: 1, seed };
        let mut r = Rng::new(seed ^ 2);
        let t = sample_token(&cfg0, &mut r, &logits) as usize;
        let probs = scaled_probs(&cfg0, &logits);
        let pmax = probs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(probs[t], pmax, "seed {seed}: p=0 must pick a max-prob token");
    }
}

#[test]
fn prop_all_neg_inf_rows_fall_back_to_argmax_without_panicking() {
    for seed in cases(20) {
        let mut rng = Rng::new(seed);
        let n = 1 + rng.below(12);
        let logits = vec![f32::NEG_INFINITY; n];
        for &top_p in &[0.0f32, 0.4, 1.0] {
            let cfg = SampleCfg { temperature: 0.8, top_p, max_new: 1, seed };
            let mut r = Rng::new(seed);
            let t = sample_token(&cfg, &mut r, &logits);
            assert!((0..n as i32).contains(&t), "seed {seed} p {top_p}: {t}");
        }
        // single -inf survivor among -inf: still in range
        let mut mixed = vec![f32::NEG_INFINITY; n];
        mixed[seed as usize % n] = 0.0;
        let cfg = SampleCfg { temperature: 1.0, top_p: 0.5, max_new: 1, seed };
        let mut r = Rng::new(seed);
        assert_eq!(sample_token(&cfg, &mut r, &mixed), (seed as usize % n) as i32);
    }
}

// ------------------------------------------------------------- stats window

#[test]
fn prop_stats_window_matches_naive_recompute() {
    for seed in cases(60) {
        let mut rng = Rng::new(seed);
        let cap = 1 + rng.below(64);
        let len = 1 + rng.below(400);
        let mut w = StatsWindow::with_capacity(cap);
        let mut all: Vec<f64> = Vec::with_capacity(len);
        for step in 0..len {
            let v = match rng.below(4) {
                0 => rng.normal() * 100.0,
                1 => rng.f64() * 1e-6,
                2 => -(rng.f64() * 50.0),
                _ => (rng.below(10) as f64) - 5.0, // clustered duplicates
            };
            w.push(v);
            all.push(v);
            if step % 37 != 0 && step + 1 != len {
                continue; // spot-check periodically + at the end
            }
            let tail: Vec<f64> =
                all[all.len().saturating_sub(cap)..].to_vec();
            assert_eq!(w.len(), tail.len(), "seed {seed} step {step}");
            assert_eq!(w.count(), all.len() as u64);
            let naive_sum: f64 = all.iter().sum();
            assert!(
                (w.sum() - naive_sum).abs() <= 1e-9 * (1.0 + naive_sum.abs()),
                "seed {seed}: sum {} vs naive {naive_sum}",
                w.sum()
            );
            let naive_mean = naive_sum / all.len() as f64;
            assert!(
                (w.mean() - naive_mean).abs() <= 1e-9 * (1.0 + naive_mean.abs()),
                "seed {seed}: mean"
            );
            assert_eq!(w.last(), all.last().copied());
            for p in [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 99.0, 100.0] {
                let want = percentile(&tail, p);
                let got = w.percentile(p);
                assert_eq!(got, want, "seed {seed} step {step} p{p}");
            }
            let kept: Vec<f64> = w.iter().collect();
            assert_eq!(kept, tail, "seed {seed}: window contents/order");
        }
    }
}

// --------------------------------------------------------------------- json

#[test]
fn prop_json_round_trip_random_trees() {
    fn random_value(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool(0.5)),
            2 => Json::Num((rng.normal() * 100.0 * 8.0).round() / 8.0),
            3 => Json::Str(
                (0..rng.below(12))
                    .map(|_| *rng.choice(&['a', 'Ω', '"', '\\', '\n', '7', ' ']))
                    .collect(),
            ),
            4 => Json::Arr((0..rng.below(5)).map(|_| random_value(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for seed in cases(80) {
        let mut rng = Rng::new(seed);
        let v = random_value(&mut rng, 3);
        let s = v.to_string();
        let v2 = Json::parse(&s).unwrap_or_else(|e| panic!("seed {seed}: {e} in {s}"));
        assert_eq!(v, v2, "seed {seed}");
        let v3 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, v3, "seed {seed} (pretty)");
    }
}
