//! Property-based tests over coordinator/data/quant invariants.
//!
//! `proptest` is not in the offline crates cache, so this uses the same
//! structure by hand: seeded random-input generators sweeping hundreds of
//! cases per invariant (no shrinking — failing seeds are printed so a case
//! can be replayed directly).

use qadx::coordinator::{merge, Checkpoint, LrSchedule, TrainCfg};
use qadx::data::{
    sources::decode_response, tasks, tokenizer as tok, BatchFactory, BatchShape, SourceKind,
    SourceSpec, TEXT_SUITES, VISION_SUITES,
};
use qadx::quant::fp::{e2m1_round, e4m3_round};
use qadx::quant::nvfp4::{self, Nvfp4Tensor};
use qadx::util::json::Json;
use qadx::util::rng::Rng;

fn cases(n: usize) -> impl Iterator<Item = u64> {
    (0..n as u64).map(|i| 0xBEEF ^ i.wrapping_mul(0x9E3779B97F4A7C15))
}

// ------------------------------------------------------------------- quant

#[test]
fn prop_fake_quant_idempotent() {
    for seed in cases(60) {
        let mut rng = Rng::new(seed);
        let rows = 1 + rng.below(24);
        let cols = 16 * (1 + rng.below(8));
        let scale = [1e-4f32, 1.0, 300.0][rng.below(3)];
        let x: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32 * scale).collect();
        let q1 = nvfp4::fake_quant(&x, rows, cols);
        let q2 = nvfp4::fake_quant(&q1, rows, cols);
        for (i, (a, b)) in q1.iter().zip(&q2).enumerate() {
            assert!(
                (a - b).abs() <= a.abs() * 1e-6 + 1e-12,
                "seed {seed}: idempotency broke at {i}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn prop_quant_error_bounded() {
    // NVFP4 worst-case relative elementwise error within a block is bounded
    // by the E2M1 grid spacing (~1/3 relative) once scales are sane.
    for seed in cases(40) {
        let mut rng = Rng::new(seed);
        let cols = 16 * (1 + rng.below(6));
        let x: Vec<f32> = (0..cols).map(|_| rng.normal() as f32).collect();
        let q = nvfp4::fake_quant(&x, 1, cols);
        let rel = nvfp4::rel_error(&x, &q);
        assert!(rel < 0.35, "seed {seed}: rel error {rel}");
    }
}

#[test]
fn prop_codes_round_trip_through_packing() {
    for seed in cases(40) {
        let mut rng = Rng::new(seed);
        let rows = 1 + rng.below(8);
        let cols = 16 * (1 + rng.below(4));
        let x: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32 * 2.0).collect();
        let t = Nvfp4Tensor::quantize(&x, rows, cols, None);
        // decode each packed code and re-encode: must be a fixed point
        for i in 0..rows * cols {
            let code = t.code_at(i);
            assert!(code & 0xf0 == 0, "nibble overflow");
            let v = qadx::quant::fp::e2m1_decode(code);
            let c2 = qadx::quant::fp::e2m1_encode(v);
            assert_eq!(qadx::quant::fp::e2m1_decode(c2), v, "seed {seed} idx {i}");
        }
    }
}

#[test]
fn prop_scalar_round_monotone() {
    for seed in cases(20) {
        let mut rng = Rng::new(seed);
        let mut xs: Vec<f32> = (0..200).map(|_| rng.normal() as f32 * 200.0).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut prev4 = f32::NEG_INFINITY;
        let mut prev2 = f32::NEG_INFINITY;
        for x in xs {
            let a = e4m3_round(x);
            let b = e2m1_round(x);
            assert!(a >= prev4, "e4m3 monotonicity at {x}");
            assert!(b >= prev2, "e2m1 monotonicity at {x}");
            prev4 = a;
            prev2 = b;
        }
    }
}

// -------------------------------------------------------------------- data

#[test]
fn prop_batches_well_formed_across_sources_and_shapes() {
    for seed in cases(40) {
        let mut rng = Rng::new(seed);
        let vision = rng.bool(0.3);
        let shape = BatchShape {
            batch: [4, 8, 16][rng.below(3)],
            seq_len: [40, 48, 64][rng.below(3)],
            vision,
            grid: 4,
            patch: 16,
            vocab: 64,
        };
        let suites = if vision { VISION_SUITES } else { TEXT_SUITES };
        let kind = match rng.below(2) {
            0 => SourceKind::Sft { p_correct: rng.f64() },
            _ => SourceKind::RandomTokens,
        };
        let spec = SourceSpec { kind, suites: suites.to_vec(), weight: 1.0 };
        let mut f = BatchFactory::new(shape, vec![spec], seed);
        let b = f.next_batch(None).expect("batch");
        assert_eq!(b.tokens.len(), shape.batch * shape.seq_len, "seed {seed}");
        assert_eq!(b.mask.len(), shape.batch * shape.seq_len);
        assert_eq!(b.pixels.is_some(), vision);
        // every token id in vocab, every mask bit 0/1, some mask per row
        assert!(b.tokens.iter().all(|&t| (0..64).contains(&t)));
        assert!(b.mask.iter().all(|&m| m == 0.0 || m == 1.0));
        for r in 0..shape.batch {
            let row = &b.mask[r * shape.seq_len..(r + 1) * shape.seq_len];
            assert!(row.iter().sum::<f32>() >= 1.0, "seed {seed} row {r} empty mask");
        }
    }
}

#[test]
fn prop_factory_deterministic_per_seed() {
    let shape = BatchShape { batch: 8, seq_len: 40, vision: false, grid: 4, patch: 16, vocab: 64 };
    for seed in cases(20) {
        let spec = SourceSpec::sft(TEXT_SUITES);
        let mut a = BatchFactory::new(shape, vec![spec.clone()], seed);
        let mut b = BatchFactory::new(shape, vec![spec], seed);
        for _ in 0..3 {
            let ba = a.next_batch(None).unwrap();
            let bb = b.next_batch(None).unwrap();
            assert_eq!(ba.tokens, bb.tokens, "seed {seed}");
            assert_eq!(ba.mask, bb.mask);
        }
    }
}

#[test]
fn prop_task_rows_decode_to_answer() {
    for seed in cases(60) {
        let mut rng = Rng::new(seed);
        let suite = *rng.choice(TEXT_SUITES);
        let s = tasks::generate(suite, &mut rng, 4, 16);
        let (tokens, _mask) = tasks::build_row(&s, &s.answer, 40);
        let prompt = tasks::prompt_tokens(&s, 40);
        let resp = decode_response(&tokens, &prompt);
        assert_eq!(resp.trim(), s.answer, "seed {seed} suite {suite:?}");
    }
}

#[test]
fn prop_tokenizer_round_trips_task_strings() {
    for seed in cases(100) {
        let mut rng = Rng::new(seed);
        let suite = *rng.choice(TEXT_SUITES);
        let s = tasks::generate(suite, &mut rng, 4, 16);
        let text = format!("{}{}", s.prompt, s.answer);
        assert_eq!(tok::decode(&tok::encode(&text)), text, "seed {seed}");
    }
}

// -------------------------------------------------------------- coordinator

#[test]
fn prop_lr_schedule_bounded_and_warmup_monotone() {
    for seed in cases(40) {
        let mut rng = Rng::new(seed);
        let steps = 50 + rng.below(500);
        let warmup = rng.below(steps / 2);
        let lr = 10f64.powf(-(2.0 + rng.f64() * 4.0));
        let cfg = TrainCfg {
            steps,
            lr,
            schedule: LrSchedule::CosineWarmup { warmup, floor: 0.1 },
            ..TrainCfg::default()
        };
        let mut prev = 0.0;
        for s in 0..steps {
            let v = cfg.lr_at(s);
            assert!(v > 0.0 && v <= lr * (1.0 + 1e-9), "seed {seed} step {s}: {v}");
            if s < warmup {
                assert!(v >= prev, "warmup must be nondecreasing");
            }
            prev = v;
        }
        // tail reaches the floor region
        assert!(cfg.lr_at(steps - 1) <= lr * 0.2 + 1e-12);
    }
}

#[test]
fn prop_topk_checkpoint_selection() {
    for seed in cases(40) {
        let mut rng = Rng::new(seed);
        let n = 1 + rng.below(20);
        let mut log = qadx::coordinator::TrainLog::default();
        for i in 0..n {
            log.checkpoints.push(Checkpoint {
                step: i,
                val_loss: rng.f64() * 10.0,
                params: vec![],
            });
        }
        let top = log.top_checkpoints();
        assert_eq!(top.len(), n);
        for w in top.windows(2) {
            assert!(w[0].val_loss <= w[1].val_loss, "seed {seed}: not sorted");
        }
    }
}

#[test]
fn prop_merge_lerp_between_endpoints() {
    for seed in cases(40) {
        let mut rng = Rng::new(seed);
        let n = 1 + rng.below(100);
        let a: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let alpha = rng.f32();
        let m = merge::lerp(&a, &b, alpha).unwrap();
        for i in 0..n {
            let lo = a[i].min(b[i]) - 1e-5;
            let hi = a[i].max(b[i]) + 1e-5;
            assert!(m[i] >= lo && m[i] <= hi, "seed {seed} idx {i}");
        }
    }
}

// --------------------------------------------------------------------- json

#[test]
fn prop_json_round_trip_random_trees() {
    fn random_value(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool(0.5)),
            2 => Json::Num((rng.normal() * 100.0 * 8.0).round() / 8.0),
            3 => Json::Str(
                (0..rng.below(12))
                    .map(|_| *rng.choice(&['a', 'Ω', '"', '\\', '\n', '7', ' ']))
                    .collect(),
            ),
            4 => Json::Arr((0..rng.below(5)).map(|_| random_value(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for seed in cases(80) {
        let mut rng = Rng::new(seed);
        let v = random_value(&mut rng, 3);
        let s = v.to_string();
        let v2 = Json::parse(&s).unwrap_or_else(|e| panic!("seed {seed}: {e} in {s}"));
        assert_eq!(v, v2, "seed {seed}");
        let v3 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, v3, "seed {seed} (pretty)");
    }
}
