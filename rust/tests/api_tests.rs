//! `qadx::api` integration tests. All of them run hermetically on the
//! reference backend over synthetic manifests (tests/common); the serve
//! path additionally runs against real AOT artifacts when they exist
//! (artifact tier).

mod common;

use std::path::Path;
use std::rc::Rc;

use qadx::api::{RecoveryMethod, ServeCfg, Session};
use qadx::coordinator::{checkpoint, RecoveryCfg};
use qadx::data::{SourceSpec, Suite};
use qadx::runtime::BackendKind;
use qadx::util::json::Json;

fn save_teacher(runs: &Path, model: &str, params: &[f32]) -> std::path::PathBuf {
    let path = runs.join("teachers").join(format!("{model}.qckp"));
    checkpoint::save(&path, params, &Json::obj(vec![])).unwrap();
    path
}

/// Session over a synthetic single-model manifest on the reference backend.
fn session_with(tag: &str, spec: qadx::runtime::SynthSpec) -> (Session, std::path::PathBuf) {
    let artifacts = common::write_artifacts(tag, &[spec]);
    let runs = common::tmp_runs(tag);
    let session = Session::builder()
        .artifacts_dir(&artifacts)
        .runs_dir(&runs)
        .backend(BackendKind::Reference)
        .build()
        .expect("reference session");
    (session, runs)
}

#[test]
fn teacher_disk_cache_then_memory_cache() {
    let spec = common::small_spec("tiny");
    let param_count = spec.entry().param_count;
    let (session, runs) = session_with("cache", spec);
    let params: Vec<f32> = (0..param_count).map(|i| i as f32 * 0.25).collect();
    let tpath = save_teacher(&runs, "tiny", &params);

    let ms = session.model("tiny").unwrap();
    assert_eq!(ms.teacher().unwrap().as_ref(), &params);

    // Remove the disk cache: a second model() + teacher() must be served
    // from the session's in-memory cache, not retrained.
    std::fs::remove_file(&tpath).unwrap();
    let ms2 = session.model("tiny").unwrap();
    assert_eq!(ms2.teacher().unwrap().as_ref(), &params);

    common::cleanup("cache");
}

#[test]
fn stale_teacher_cache_is_not_served() {
    let (session, runs) = session_with("stale", common::small_spec("tiny"));
    // Wrong parameter count: must trigger retraining (which fails fast
    // here — "tiny" has no teacher pipeline) instead of serving
    // wrong-size weights.
    save_teacher(&runs, "tiny", &[1.0, 2.0]);

    let ms = session.model("tiny").unwrap();
    let res = ms.teacher();
    assert!(res.is_err(), "stale cache must not be served");

    common::cleanup("stale");
}

/// A seventh recovery method: one trait impl + one registry entry, no
/// enum edits, no dispatch-site edits.
struct EchoTeacher;

impl RecoveryMethod for EchoTeacher {
    fn name(&self) -> &str {
        "echo"
    }
    fn step_key(&self) -> Option<&str> {
        None // training-free: students are the teacher weights
    }
    fn fwd_key(&self) -> &str {
        "fwd_bf16"
    }
}

#[test]
fn seventh_method_is_trait_impl_plus_registration() {
    let spec = common::small_spec("tiny");
    let param_count = spec.entry().param_count;
    let artifacts = common::write_artifacts("seventh", &[spec]);
    let runs = common::tmp_runs("seventh");
    let params: Vec<f32> = (0..param_count).map(|i| (i as f32).sin()).collect();
    save_teacher(&runs, "tiny", &params);
    let session = Session::builder()
        .artifacts_dir(&artifacts)
        .runs_dir(&runs)
        .backend(BackendKind::Reference)
        .register_method(Rc::new(EchoTeacher))
        .build()
        .expect("reference session");

    // Resolvable by name alongside the six built-ins.
    let echo = session.method("echo").unwrap();
    assert_eq!(session.methods().names().len(), 7);

    let ms = session.model("tiny").unwrap();
    let cfg = RecoveryCfg::new(vec![SourceSpec::sft(&[Suite::Math500])], 1e-4, 10);
    let out = ms.recover(&*echo, &cfg).unwrap();
    assert_eq!(out.method, "echo");
    assert_eq!(out.params, params);

    // Checkpoint paths derive from the registered name.
    let path = ms.checkpoint_path(&*echo);
    assert!(path.to_string_lossy().ends_with("tiny-echo.qckp"), "{path:?}");
    ms.save_recovered(&*echo, &out).unwrap();
    assert_eq!(ms.load_recovered(&*echo).unwrap(), params);
    // Training-free methods evaluate the teacher weights.
    assert_eq!(ms.method_params(&*echo).unwrap(), params);

    common::cleanup("seventh");
}

/// The full coalescing-server behavior contract, shared by both tiers.
fn assert_serve_coalesces(session: &Session, model: &str) {
    let ms = session.model(model).unwrap();
    let b = ms.rt.model.batch;
    let n = 2 * b + (b + 1) / 2; // ragged tail whenever b > 1

    let mut cfg = ServeCfg::default();
    cfg.sample.max_new = 2;
    cfg.max_batch_delay_ms = 1e9; // only fullness / drain flush batches
    let mut server = ms.server("fwd_bf16", &cfg).unwrap();
    for i in 0..n {
        server.submit(vec![1, 4 + (i % 8) as i32, 3]).unwrap();
    }
    let responses = server.drain().unwrap();
    assert_eq!(responses.len(), n, "every request must complete");
    let ids: std::collections::BTreeSet<u64> = responses.iter().map(|r| r.id).collect();
    assert_eq!(ids.len(), n);
    for r in &responses {
        assert_eq!(r.row.len(), ms.rt.model.seq_len);
    }

    let st = server.stats();
    assert_eq!(st.requests, n);
    assert_eq!(st.batches, (n + b - 1) / b);
    assert_eq!(st.fill_ratios.len(), st.batches);
    assert_eq!(st.fill_ratios.count(), st.batches as u64);
    let tail = n % b;
    if tail > 0 {
        let last = st.fill_ratios.last().unwrap();
        assert!((last - tail as f64 / b as f64).abs() < 1e-12, "fill {last}");
    }
    assert!(st.fill_ratios.iter().all(|f| f > 0.0 && f <= 1.0));
    // queue-wait vs execute split: one sample of each per request, waits
    // and execute times non-negative, and wait + execute ≈ latency.
    assert_eq!(st.queue_wait_ms.count(), n as u64);
    assert_eq!(st.execute_ms.count(), n as u64);
    assert!(st.queue_wait_ms.iter().all(|w| w >= 0.0));
    assert!(st.execute_ms.iter().all(|e| e > 0.0));
    let lat_sum: f64 = st.latencies_ms.iter().sum();
    let split_sum: f64 =
        st.queue_wait_ms.iter().sum::<f64>() + st.execute_ms.iter().sum::<f64>();
    assert!(
        (lat_sum - split_sum).abs() <= 0.05 * lat_sum.max(1.0),
        "latency {lat_sum} vs wait+execute {split_sum}"
    );
}

#[test]
fn serve_handle_coalesces_hermetically() {
    let (session, _runs) = session_with("serve_ref", common::small_spec("size-serve"));
    assert_serve_coalesces(&session, "size-serve");
    common::cleanup("serve_ref");
}

#[test]
fn serve_quantized_fwd_path_hermetically() {
    // The nvfp4 serving path end-to-end: quantized forward + frontier
    // decode under the coalescer.
    let (session, _runs) = session_with("serve_ref_q", common::small_spec("size-serveq"));
    let ms = session.model("size-serveq").unwrap();
    let mut cfg = ServeCfg::default();
    cfg.sample.max_new = 2;
    let mut server = ms.server("fwd_nvfp4", &cfg).unwrap();
    for i in 0..3 {
        server.submit(vec![1, 5 + i, 3]).unwrap();
    }
    let responses = server.drain().unwrap();
    assert_eq!(responses.len(), 3);
    assert!(server.stats().gen_tokens > 0);
    common::cleanup("serve_ref_q");
}

#[test]
fn serve_handle_coalesces_over_real_artifacts() {
    let Some(dir) = common::real_artifacts_dir() else {
        common::artifact_tier_disabled("serve_coalesce");
        return;
    };
    let runs = common::tmp_runs("serve_art");
    let session = match Session::builder().artifacts_dir(&dir).runs_dir(&runs).build() {
        Ok(s) => s,
        Err(e) => panic!("artifacts exist but session failed: {e:#}"),
    };
    assert_serve_coalesces(&session, "size-xs");
    common::cleanup("serve_art");
}
