//! `qadx::api` integration tests. All of them run hermetically on the
//! reference backend over synthetic manifests (tests/common); the serve
//! path additionally runs against real AOT artifacts when they exist
//! (artifact tier).

mod common;

use std::path::Path;
use std::rc::Rc;

use qadx::api::{DecodeMode, RecoveryMethod, ServeCfg, ServeWeights, Session};
use qadx::coordinator::{checkpoint, RecoveryCfg};
use qadx::data::tokenizer as tok;
use qadx::data::{SourceSpec, Suite};
use qadx::runtime::BackendKind;
use qadx::util::json::Json;

fn save_teacher(runs: &Path, model: &str, params: &[f32]) -> std::path::PathBuf {
    let path = runs.join("teachers").join(format!("{model}.qckp"));
    checkpoint::save(&path, params, &Json::obj(vec![])).unwrap();
    path
}

/// Session over a synthetic single-model manifest on the reference backend.
fn session_with(tag: &str, spec: qadx::runtime::SynthSpec) -> (Session, std::path::PathBuf) {
    let artifacts = common::write_artifacts(tag, &[spec]);
    let runs = common::tmp_runs(tag);
    let session = Session::builder()
        .artifacts_dir(&artifacts)
        .runs_dir(&runs)
        .backend(BackendKind::Reference)
        .build()
        .expect("reference session");
    (session, runs)
}

#[test]
fn teacher_disk_cache_then_memory_cache() {
    let spec = common::small_spec("tiny");
    let param_count = spec.entry().param_count;
    let (session, runs) = session_with("cache", spec);
    let params: Vec<f32> = (0..param_count).map(|i| i as f32 * 0.25).collect();
    let tpath = save_teacher(&runs, "tiny", &params);

    let ms = session.model("tiny").unwrap();
    assert_eq!(ms.teacher().unwrap().as_ref(), &params);

    // Remove the disk cache: a second model() + teacher() must be served
    // from the session's in-memory cache, not retrained.
    std::fs::remove_file(&tpath).unwrap();
    let ms2 = session.model("tiny").unwrap();
    assert_eq!(ms2.teacher().unwrap().as_ref(), &params);

    common::cleanup("cache");
}

#[test]
fn stale_teacher_cache_is_not_served() {
    let (session, runs) = session_with("stale", common::small_spec("tiny"));
    // Wrong parameter count: must trigger retraining (which fails fast
    // here — "tiny" has no teacher pipeline) instead of serving
    // wrong-size weights.
    save_teacher(&runs, "tiny", &[1.0, 2.0]);

    let ms = session.model("tiny").unwrap();
    let res = ms.teacher();
    assert!(res.is_err(), "stale cache must not be served");

    common::cleanup("stale");
}

/// A seventh recovery method: one trait impl + one registry entry, no
/// enum edits, no dispatch-site edits.
struct EchoTeacher;

impl RecoveryMethod for EchoTeacher {
    fn name(&self) -> &str {
        "echo"
    }
    fn step_key(&self) -> Option<&str> {
        None // training-free: students are the teacher weights
    }
    fn fwd_key(&self) -> &str {
        "fwd_bf16"
    }
}

#[test]
fn seventh_method_is_trait_impl_plus_registration() {
    let spec = common::small_spec("tiny");
    let param_count = spec.entry().param_count;
    let artifacts = common::write_artifacts("seventh", &[spec]);
    let runs = common::tmp_runs("seventh");
    let params: Vec<f32> = (0..param_count).map(|i| (i as f32).sin()).collect();
    save_teacher(&runs, "tiny", &params);
    let session = Session::builder()
        .artifacts_dir(&artifacts)
        .runs_dir(&runs)
        .backend(BackendKind::Reference)
        .register_method(Rc::new(EchoTeacher))
        .build()
        .expect("reference session");

    // Resolvable by name alongside the six built-ins.
    let echo = session.method("echo").unwrap();
    assert_eq!(session.methods().names().len(), 7);

    let ms = session.model("tiny").unwrap();
    let cfg = RecoveryCfg::new(vec![SourceSpec::sft(&[Suite::Math500])], 1e-4, 10);
    let out = ms.recover(&*echo, &cfg).unwrap();
    assert_eq!(out.method, "echo");
    assert_eq!(out.params, params);

    // Checkpoint paths derive from the registered name.
    let path = ms.checkpoint_path(&*echo);
    assert!(path.to_string_lossy().ends_with("tiny-echo.qckp"), "{path:?}");
    ms.save_recovered(&*echo, &out).unwrap();
    assert_eq!(ms.load_recovered(&*echo).unwrap(), params);
    // Training-free methods evaluate the teacher weights.
    assert_eq!(ms.method_params(&*echo).unwrap(), params);

    common::cleanup("seventh");
}

/// The full coalescing-server behavior contract, shared by both tiers.
/// Pinned to `DecodeMode::Full` so the run-to-completion batch path is
/// what actually runs even on backends with stateful decode (the
/// continuous scheduler has its own contract tests below).
fn assert_serve_coalesces(session: &Session, model: &str) {
    let ms = session.model(model).unwrap();
    let b = ms.rt.model.batch;
    let n = 2 * b + (b + 1) / 2; // ragged tail whenever b > 1

    let mut cfg = ServeCfg::default();
    cfg.sample.max_new = 2;
    cfg.max_batch_delay_ms = 1e9; // only fullness / drain flush batches
    cfg.decode = DecodeMode::Full;
    let mut server = ms.server("fwd_bf16", &cfg).unwrap();
    assert!(!server.continuous(), "decode=full must select the coalescing path");
    for i in 0..n {
        server.submit(vec![1, 4 + (i % 8) as i32, 3]).unwrap();
    }
    let responses = server.drain().unwrap();
    assert_eq!(responses.len(), n, "every request must complete");
    let ids: std::collections::BTreeSet<u64> = responses.iter().map(|r| r.id).collect();
    assert_eq!(ids.len(), n);
    for r in &responses {
        assert_eq!(r.row.len(), ms.rt.model.seq_len);
    }

    let st = server.stats();
    assert_eq!(st.requests, n);
    assert_eq!(st.batches, (n + b - 1) / b);
    assert_eq!(st.fill_ratios.len(), st.batches);
    assert_eq!(st.fill_ratios.count(), st.batches as u64);
    let tail = n % b;
    if tail > 0 {
        let last = st.fill_ratios.last().unwrap();
        assert!((last - tail as f64 / b as f64).abs() < 1e-12, "fill {last}");
    }
    assert!(st.fill_ratios.iter().all(|f| f > 0.0 && f <= 1.0));
    // queue-wait vs execute split: one sample of each per request, waits
    // and execute times non-negative, and wait + execute ≈ latency.
    assert_eq!(st.queue_wait_ms.count(), n as u64);
    assert_eq!(st.execute_ms.count(), n as u64);
    // batch mode surfaces tokens only at completion: TTFT == latency
    assert_eq!(st.ttft_ms.count(), n as u64);
    assert_eq!(st.decode_rounds, 0);
    assert!(st.queue_wait_ms.iter().all(|w| w >= 0.0));
    assert!(st.execute_ms.iter().all(|e| e > 0.0));
    let lat_sum: f64 = st.latencies_ms.iter().sum();
    let split_sum: f64 =
        st.queue_wait_ms.iter().sum::<f64>() + st.execute_ms.iter().sum::<f64>();
    assert!(
        (lat_sum - split_sum).abs() <= 0.05 * lat_sum.max(1.0),
        "latency {lat_sum} vs wait+execute {split_sum}"
    );
}

#[test]
fn serve_handle_coalesces_hermetically() {
    let (session, _runs) = session_with("serve_ref", common::small_spec("size-serve"));
    assert_serve_coalesces(&session, "size-serve");
    common::cleanup("serve_ref");
}

#[test]
fn serve_quantized_fwd_path_hermetically() {
    // The nvfp4 serving path end-to-end: quantized prefill/step decode
    // under the continuous scheduler (Auto resolves to continuous on the
    // reference backend).
    let (session, _runs) = session_with("serve_ref_q", common::small_spec("size-serveq"));
    let ms = session.model("size-serveq").unwrap();
    let mut cfg = ServeCfg::default();
    cfg.sample.max_new = 2;
    let mut server = ms.server("fwd_nvfp4", &cfg).unwrap();
    assert!(server.continuous(), "reference backend should serve continuously by default");
    for i in 0..3 {
        server.submit(vec![1, 5 + i, 3]).unwrap();
    }
    let responses = server.drain().unwrap();
    assert_eq!(responses.len(), 3);
    assert!(server.stats().gen_tokens > 0);
    common::cleanup("serve_ref_q");
}

/// The deterministic "clock" model (see `common::clock_spec_and_params`):
/// a row with prompt length L generates exactly 7 - L tokens, so finish
/// times are a pure function of prompt length.
fn clock_spec_and_params() -> (qadx::runtime::SynthSpec, Vec<f32>) {
    common::clock_spec_and_params("clock-serve")
}

#[test]
fn continuous_scheduler_admits_mid_generation() {
    // Two slots, three requests with finish times fixed by the clock
    // model: A (prompt len 4) EOSes two rounds before B (len 2), freeing
    // a slot while B is still generating — C must be admitted into it
    // before the batch drains, and every row must still be exact.
    let (spec, params) = clock_spec_and_params();
    let (session, _runs) = session_with("serve_cont", spec);
    let ms = session.model("clock-serve").unwrap();
    let mut cfg = ServeCfg::default();
    cfg.sample = qadx::eval::SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 8, seed: 0 };
    cfg.weights = ServeWeights::Params(params);
    cfg.max_slots = 2;
    let mut server = ms.server("fwd_bf16", &cfg).unwrap();
    assert!(server.continuous());

    let a = server.submit(vec![1, 4, 4, 4]).unwrap(); // gen 3 (EOS at pos 6)
    let b = server.submit(vec![1, 4]).unwrap(); //        gen 5 (EOS at pos 6)
    assert_eq!(server.in_flight(), 2, "both requests admitted immediately");
    let c = server.submit(vec![1, 4, 4, 4]).unwrap(); // queued: slots full
    assert_eq!(server.queued(), 1);

    let responses = server.drain().unwrap();
    assert_eq!(responses.len(), 3, "every request completes");
    let by_id: std::collections::HashMap<u64, _> =
        responses.iter().map(|r| (r.id, r)).collect();
    assert_eq!(by_id[&a].gen_tokens, 3);
    assert_eq!(by_id[&b].gen_tokens, 5);
    assert_eq!(by_id[&c].gen_tokens, 3);
    // exact rows: prompt, fillers, EOS at position 6, PAD tail
    let mut want_a = vec![tok::PAD; 12];
    want_a[..4].copy_from_slice(&[1, 4, 4, 4]);
    want_a[4] = 5;
    want_a[5] = 5;
    want_a[6] = tok::EOS;
    assert_eq!(by_id[&a].row, want_a);
    assert_eq!(by_id[&c].row, want_a);

    let st = server.stats();
    assert_eq!(st.requests, 3);
    assert!(
        st.mid_gen_admissions >= 1,
        "C must take A's freed slot mid-generation: {}",
        st.summary()
    );
    // A and C each need 2 post-admission rounds, B needs 4; C rides in
    // A's freed slot, so the whole mix drains in exactly 4 rounds.
    assert_eq!(st.decode_rounds, 4, "{}", st.summary());
    assert_eq!(st.ttft_ms.count(), 3, "one TTFT sample per request");
    // inter-token gaps: one per generated token after the first of each
    // request -> gen_tokens - requests
    assert_eq!(st.inter_token_ms.count(), (st.gen_tokens - st.requests) as u64);
    assert_eq!(st.slot_occupancy.count(), st.decode_rounds as u64);
    // per-request TTFT is at most the full latency
    for r in &responses {
        assert!(r.ttft_ms <= r.latency_ms + 1e-6, "ttft {} > latency {}", r.ttft_ms, r.latency_ms);
    }
    let s = st.summary();
    assert!(s.contains("ttft p50"), "{s}");
    assert!(s.contains("mid-gen"), "{s}");
    common::cleanup("serve_cont");
}

#[test]
fn continuous_scheduler_honors_max_new() {
    // The clock model would keep emitting fillers until EOS at position
    // 6; with max_new = 2 the request must stop after exactly 2 tokens
    // (the stateless path's cap), with no EOS in the row.
    let (spec, params) = clock_spec_and_params();
    let (session, _runs) = session_with("serve_cap", spec);
    let ms = session.model("clock-serve").unwrap();
    let mut cfg = ServeCfg::default();
    cfg.sample = qadx::eval::SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 2, seed: 0 };
    cfg.weights = ServeWeights::Params(params);
    let mut server = ms.server("fwd_bf16", &cfg).unwrap();
    assert!(server.continuous());
    server.submit(vec![1, 4]).unwrap();
    let responses = server.drain().unwrap();
    assert_eq!(responses.len(), 1);
    assert_eq!(responses[0].gen_tokens, 2);
    let mut want = vec![tok::PAD; 12];
    want[..2].copy_from_slice(&[1, 4]);
    want[2] = 5;
    want[3] = 5;
    assert_eq!(responses[0].row, want);
    common::cleanup("serve_cap");
}

#[test]
fn continuous_scheduler_poll_advances_one_round() {
    let (spec, params) = clock_spec_and_params();
    let (session, _runs) = session_with("serve_poll", spec);
    let ms = session.model("clock-serve").unwrap();
    let mut cfg = ServeCfg::default();
    cfg.sample = qadx::eval::SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 8, seed: 0 };
    cfg.weights = ServeWeights::Params(params);
    cfg.max_slots = 1;
    let mut server = ms.server("fwd_bf16", &cfg).unwrap();
    // prompt len 4 -> first token at admission, then 2 more rounds to EOS
    server.submit(vec![1, 4, 4, 4]).unwrap();
    assert_eq!(server.in_flight(), 1);
    assert_eq!(server.poll().unwrap(), 0, "round 1: still generating");
    assert_eq!(server.poll().unwrap(), 1, "round 2 hits EOS");
    assert_eq!(server.in_flight(), 0);
    let responses = server.drain().unwrap();
    assert_eq!(responses.len(), 1);
    assert_eq!(responses[0].gen_tokens, 3);
    common::cleanup("serve_poll");
}

#[test]
fn continuous_serve_telemetry_carries_ttft_fields() {
    let (spec, params) = clock_spec_and_params();
    let artifacts = common::write_artifacts("serve_tel", &[spec]);
    let runs = common::tmp_runs("serve_tel");
    let session = Session::builder()
        .artifacts_dir(&artifacts)
        .runs_dir(&runs)
        .backend(BackendKind::Reference)
        .build()
        .unwrap();
    let ms = session.model("clock-serve").unwrap();
    let tel_path = runs.join("serve_events.jsonl");
    let mut cfg = ServeCfg::default();
    cfg.sample = qadx::eval::SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 8, seed: 0 };
    cfg.weights = ServeWeights::Params(params);
    cfg.telemetry = Some(tel_path.clone());
    let mut server = ms.server("fwd_bf16", &cfg).unwrap();
    server.submit(vec![1, 4, 4, 4]).unwrap();
    server.submit(vec![1, 4]).unwrap();
    let responses = server.drain().unwrap();
    assert_eq!(responses.len(), 2);
    drop(server);
    let log = std::fs::read_to_string(&tel_path).unwrap();
    assert!(log.contains("\"event\":\"compile\""), "{log}");
    assert!(log.contains("\"mode\":\"continuous\""), "{log}");
    let request_events: Vec<&str> =
        log.lines().filter(|l| l.contains("\"event\":\"request\"")).collect();
    assert_eq!(request_events.len(), 2, "{log}");
    for ev in request_events {
        assert!(ev.contains("\"ttft_ms\""), "{ev}");
        assert!(ev.contains("\"latency_ms\""), "{ev}");
        assert!(ev.contains("\"gen_tokens\""), "{ev}");
    }
    common::cleanup("serve_tel");
}

#[test]
fn serve_decode_step_mode_is_honored_and_full_mode_keeps_batches() {
    let (session, _runs) = session_with("serve_modes", common::small_spec("size-modes"));
    let ms = session.model("size-modes").unwrap();
    // step: required and available on the reference backend
    let mut cfg = ServeCfg::default();
    cfg.sample.max_new = 2;
    cfg.decode = DecodeMode::Step;
    let mut server = ms.server("fwd_bf16", &cfg).unwrap();
    assert!(server.continuous());
    server.submit(vec![1, 5, 3]).unwrap();
    let responses = server.drain().unwrap();
    assert_eq!(responses.len(), 1);
    assert!(server.stats().decode_rounds >= 1 || responses[0].gen_tokens == 1);
    // full: the coalescing path, batches counted
    let mut cfg = ServeCfg::default();
    cfg.sample.max_new = 2;
    cfg.decode = DecodeMode::Full;
    let mut server = ms.server("fwd_bf16", &cfg).unwrap();
    assert!(!server.continuous());
    server.submit(vec![1, 5, 3]).unwrap();
    let responses = server.drain().unwrap();
    assert_eq!(responses.len(), 1);
    assert_eq!(server.stats().batches, 1);
    common::cleanup("serve_modes");
}

#[test]
fn serve_handle_coalesces_over_real_artifacts() {
    let Some(dir) = common::real_artifacts_dir() else {
        common::artifact_tier_disabled("serve_coalesce");
        return;
    };
    let runs = common::tmp_runs("serve_art");
    let session = match Session::builder().artifacts_dir(&dir).runs_dir(&runs).build() {
        Ok(s) => s,
        Err(e) => panic!("artifacts exist but session failed: {e:#}"),
    };
    assert_serve_coalesces(&session, "size-xs");
    common::cleanup("serve_art");
}
