//! `qadx::api` integration tests. Most run against a minimal synthetic
//! manifest (no AOT artifacts needed); the serve test additionally runs
//! against real artifacts when they exist, mirroring runtime_smoke's
//! skip-with-message convention.

use std::path::{Path, PathBuf};
use std::rc::Rc;

use qadx::api::{RecoveryMethod, ServeCfg, Session};
use qadx::coordinator::{checkpoint, RecoveryCfg};
use qadx::data::{SourceSpec, Suite};
use qadx::util::json::Json;

const PARAM_COUNT: usize = 8;

/// Write a minimal-but-valid artifacts dir: a manifest with one model
/// ("tiny"), no artifact files. Engine construction only needs the
/// manifest + a PJRT CPU client.
fn fake_artifacts(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("qadx_api_test_{tag}")).join("artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    let n_scalars = 8;
    let manifest = format!(
        r#"{{
  "version": 4,
  "vocab": 64,
  "special": {{"pad": 0, "bos": 1, "eos": 2, "sep": 3}},
  "n_scalars": {n_scalars},
  "scalar_names": ["step", "loss", "kl", "ce", "grad_norm", "lr", "r0", "r1"],
  "models": {{
    "tiny": {{
      "d_model": 4, "n_heads": 1, "d_ff": 8,
      "blocks": ["attn"],
      "vocab": 64, "seq_len": 8, "batch": 2,
      "vision": false, "vision_grid": 0, "vision_patch": 0,
      "param_count": {PARAM_COUNT},
      "state_len": {state_len},
      "quant": {{"weights": "nvfp4", "acts": "bf16", "impl": "ref",
                 "skip_attention": false, "skip_first": 0, "skip_last": 0}},
      "params": [{{"name": "embed", "shape": [2, 4], "offset": 0, "size": {PARAM_COUNT}}}],
      "artifacts": {{}}
    }}
  }}
}}"#,
        state_len = 3 * PARAM_COUNT + n_scalars,
    );
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    dir
}

fn tmp_runs(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("qadx_api_test_{tag}")).join("runs");
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn save_teacher(runs: &Path, model: &str, params: &[f32]) -> PathBuf {
    let path = runs.join("teachers").join(format!("{model}.qckp"));
    checkpoint::save(&path, params, &Json::obj(vec![])).unwrap();
    path
}

fn build_session(artifacts: &Path, runs: &Path) -> Option<Session> {
    match Session::builder().artifacts_dir(artifacts).runs_dir(runs).build() {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping: cannot build session ({e:#})");
            None
        }
    }
}

#[test]
fn teacher_disk_cache_then_memory_cache() {
    let artifacts = fake_artifacts("cache");
    let runs = tmp_runs("cache");
    let params: Vec<f32> = (0..PARAM_COUNT).map(|i| i as f32 * 0.25).collect();
    let tpath = save_teacher(&runs, "tiny", &params);
    let Some(session) = build_session(&artifacts, &runs) else { return };

    let ms = session.model("tiny").unwrap();
    assert_eq!(ms.teacher().unwrap().as_ref(), &params);

    // Remove the disk cache: a second model() + teacher() must be served
    // from the session's in-memory cache, not retrained.
    std::fs::remove_file(&tpath).unwrap();
    let ms2 = session.model("tiny").unwrap();
    assert_eq!(ms2.teacher().unwrap().as_ref(), &params);

    std::fs::remove_dir_all(artifacts.parent().unwrap()).ok();
}

#[test]
fn stale_teacher_cache_is_not_served() {
    let artifacts = fake_artifacts("stale");
    let runs = tmp_runs("stale");
    // Wrong parameter count: must trigger retraining (which fails fast
    // here — the fake manifest has no step artifacts) instead of serving
    // wrong-size weights.
    save_teacher(&runs, "tiny", &[1.0, 2.0]);
    let Some(session) = build_session(&artifacts, &runs) else { return };

    let ms = session.model("tiny").unwrap();
    let res = ms.teacher();
    assert!(res.is_err(), "stale cache must not be served");

    std::fs::remove_dir_all(artifacts.parent().unwrap()).ok();
}

/// A seventh recovery method: one trait impl + one registry entry, no
/// enum edits, no dispatch-site edits.
struct EchoTeacher;

impl RecoveryMethod for EchoTeacher {
    fn name(&self) -> &str {
        "echo"
    }
    fn step_key(&self) -> Option<&str> {
        None // training-free: students are the teacher weights
    }
    fn fwd_key(&self) -> &str {
        "fwd_bf16"
    }
}

#[test]
fn seventh_method_is_trait_impl_plus_registration() {
    let artifacts = fake_artifacts("seventh");
    let runs = tmp_runs("seventh");
    let params: Vec<f32> = (0..PARAM_COUNT).map(|i| (i as f32).sin()).collect();
    save_teacher(&runs, "tiny", &params);
    let session = match Session::builder()
        .artifacts_dir(&artifacts)
        .runs_dir(&runs)
        .register_method(Rc::new(EchoTeacher))
        .build()
    {
        Ok(s) => s,
        Err(e) => {
            eprintln!("skipping: cannot build session ({e:#})");
            return;
        }
    };

    // Resolvable by name alongside the six built-ins.
    let echo = session.method("echo").unwrap();
    assert_eq!(session.methods().names().len(), 7);

    let ms = session.model("tiny").unwrap();
    let cfg = RecoveryCfg::new(vec![SourceSpec::sft(&[Suite::Math500])], 1e-4, 10);
    let out = ms.recover(&*echo, &cfg).unwrap();
    assert_eq!(out.method, "echo");
    assert_eq!(out.params, params);

    // Checkpoint paths derive from the registered name.
    let path = ms.checkpoint_path(&*echo);
    assert!(path.to_string_lossy().ends_with("tiny-echo.qckp"), "{path:?}");
    ms.save_recovered(&*echo, &out).unwrap();
    assert_eq!(ms.load_recovered(&*echo).unwrap(), params);
    // Training-free methods evaluate the teacher weights.
    assert_eq!(ms.method_params(&*echo).unwrap(), params);

    std::fs::remove_dir_all(artifacts.parent().unwrap()).ok();
}

#[test]
fn serve_handle_coalesces_over_real_artifacts() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let runs = tmp_runs("serve");
    let Some(session) = build_session(&dir, &runs) else { return };
    let ms = session.model("size-xs").unwrap();
    let b = ms.rt.model.batch;
    let n = 2 * b + (b + 1) / 2; // ragged tail whenever b > 1

    let mut cfg = ServeCfg::default();
    cfg.sample.max_new = 2;
    cfg.max_batch_delay_ms = 1e9; // only fullness / drain flush batches
    let mut server = ms.server("fwd_bf16", &cfg).unwrap();
    for i in 0..n {
        server.submit(vec![1, 4 + (i % 8) as i32, 3]).unwrap();
    }
    let responses = server.drain().unwrap();
    assert_eq!(responses.len(), n, "every request must complete");
    let ids: std::collections::BTreeSet<u64> = responses.iter().map(|r| r.id).collect();
    assert_eq!(ids.len(), n);

    let st = server.stats();
    assert_eq!(st.requests, n);
    assert_eq!(st.batches, (n + b - 1) / b);
    assert_eq!(st.fill_ratios.len(), st.batches);
    assert_eq!(st.fill_ratios.count(), st.batches as u64);
    let tail = n % b;
    if tail > 0 {
        let last = st.fill_ratios.last().unwrap();
        assert!((last - tail as f64 / b as f64).abs() < 1e-12, "fill {last}");
    }
    assert!(st.fill_ratios.iter().all(|f| f > 0.0 && f <= 1.0));

    std::fs::remove_dir_all(runs.parent().unwrap()).ok();
}
