//! Hermetic distillation-pipeline integration suite: the paper's central
//! loop — teacher post-training → QAD/QAT recovery → distribution eval —
//! executed end-to-end on the reference backend over synthetic manifests.
//! This is the code path that embodies the paper, running with zero
//! artifacts and zero XLA on every machine.

mod common;

use qadx::api::Session;
use qadx::coordinator::{rl_stage, RlCfg};
use qadx::data::{shape_for, BatchFactory, SourceKind, SourceSpec, Suite};
use qadx::eval::{eval_distribution, SampleCfg};
use qadx::runtime::{BackendKind, DeviceState, ModelRuntime};

fn session_for(tag: &str, name: &str, scale: f64) -> Session {
    let artifacts = common::write_artifacts(tag, &[common::small_spec(name)]);
    Session::builder()
        .artifacts_dir(&artifacts)
        .runs_dir(common::tmp_runs(tag))
        .backend(BackendKind::Reference)
        .scale(scale)
        .build()
        .expect("reference session")
}

#[test]
fn teacher_pipeline_trains_and_caches() {
    // "size-*" models get the short clean-SFT pipeline; scale clamps each
    // stage to the 8-step minimum.
    let session = session_for("dst_teacher", "size-dst", 0.001);
    let ms = session.model("size-dst").unwrap();
    let teacher = ms.teacher().unwrap();
    assert_eq!(teacher.len(), ms.rt.model.param_count);
    assert!(teacher.iter().all(|v| v.is_finite()));
    // Second resolution comes from the cache and is identical.
    let again = ms.teacher().unwrap();
    assert_eq!(teacher.as_ref(), again.as_ref());
    // The disk cache landed in runs/teachers.
    assert!(session.runs_dir().join("teachers").join("size-dst.qckp").exists());
    common::cleanup("dst_teacher");
}

#[test]
fn qad_recovery_produces_students_and_curves() {
    let session = session_for("dst_qad", "size-dst", 0.001);
    let ms = session.model("size-dst").unwrap();
    let teacher = ms.teacher().unwrap();

    let qad = session.method("qad").unwrap();
    let mut cfg = ms.default_recovery_cfg(10);
    cfg.train.lr = 3e-4;
    let out = ms.recover(&*qad, &cfg).unwrap();
    assert_eq!(out.method, "qad");
    assert_eq!(out.params.len(), teacher.len());
    assert!(out.params.iter().all(|v| v.is_finite()));
    // Training actually moved the weights and logged curves.
    assert!(out.params.iter().zip(teacher.iter()).any(|(a, b)| a != b));
    assert!(!out.curve.is_empty(), "loss curve empty");
    assert!(!out.val_curve.is_empty(), "val curve empty");
    assert!(out.curve.iter().all(|(_, l)| l.is_finite() && *l >= 0.0));

    // Persist + reload through the method-derived checkpoint path.
    ms.save_recovered(&*qad, &out).unwrap();
    assert_eq!(ms.load_recovered(&*qad).unwrap(), out.params);
    common::cleanup("dst_qad");
}

#[test]
fn distribution_eval_quantifies_the_ptq_gap() {
    let session = session_for("dst_eval", "size-dst", 0.001);
    let ms = session.model("size-dst").unwrap();
    let teacher = ms.teacher().unwrap();
    let rt = &ms.rt;
    let shape = shape_for(&rt.model);
    let spec = SourceSpec::sft(&[Suite::Math500, Suite::Gpqa]);

    // Teacher vs itself through the quantized eval: the PTQ gap, > 0.
    let mut f1 = BatchFactory::new(shape, vec![spec.clone()], 0xE7A1);
    let q = eval_distribution(
        session.engine(), rt, "eval_nvfp4", &teacher, &teacher, &mut f1, &spec, 2,
    )
    .unwrap();
    assert!(q.kl > 0.0, "quantized KL should be positive: {q:?}");
    assert!(q.tokens > 0.0);

    // Teacher vs itself through the BF16 eval: KL exactly ~0.
    let mut f2 = BatchFactory::new(shape, vec![spec.clone()], 0xE7A1);
    let b = eval_distribution(
        session.engine(), rt, "eval_bf16", &teacher, &teacher, &mut f2, &spec, 2,
    )
    .unwrap();
    assert!(b.kl.abs() < 1e-5, "bf16 self-KL {b:?}");
    assert!(b.ce > 0.0);
    common::cleanup("dst_eval");
}

#[test]
fn qat_recovery_runs_through_the_generic_trainer() {
    // QAT (CE loss, quantized forward) through the same method registry.
    let session = session_for("dst_qat", "size-dst", 0.001);
    let ms = session.model("size-dst").unwrap();
    let qat = session.method("qat").unwrap();
    let cfg = ms.default_recovery_cfg(8);
    let out = ms.recover(&*qat, &cfg).unwrap();
    assert_eq!(out.method, "qat");
    assert!(!out.curve.is_empty());
    assert!(out.params.iter().all(|v| v.is_finite()));
    common::cleanup("dst_qat");
}

#[test]
fn generation_backed_recovery_uses_the_teacher_generator() {
    // RL-generated data sources pull completions from the BF16 teacher
    // sampler mid-training — the full generate-inside-train loop.
    let session = session_for("dst_gen", "size-dst", 0.001);
    let ms = session.model("size-dst").unwrap();
    let teacher = ms.teacher().unwrap();
    let qad = session.method("qad").unwrap();
    let mut cfg = ms.default_recovery_cfg(4);
    cfg.data = vec![SourceSpec {
        kind: SourceKind::RlGenerated,
        suites: vec![Suite::Math500],
        weight: 1.0,
    }];
    cfg.teacher_sample = SampleCfg { temperature: 1.0, top_p: 1.0, max_new: 4, seed: 9 };
    let out = ms.recover_from(&*qad, &teacher, &cfg).unwrap();
    assert_eq!(out.params.len(), teacher.len());
    assert!(!out.curve.is_empty());
    common::cleanup("dst_gen");
}

#[test]
fn rl_stage_improves_or_holds_reward_and_updates_state() {
    // GRPO-style RL with rollouts sampled from the live device state
    // (fwd_bf16_state) — hermetic on the reference backend.
    let session = session_for("dst_rl", "size-dst", 0.001);
    let ms = session.model("size-dst").unwrap();
    let teacher = ms.teacher().unwrap();
    let rt = ModelRuntime::new(session.engine(), "size-dst").unwrap();
    let mut state = DeviceState::from_params(&rt, &teacher).unwrap();
    let cfg = RlCfg {
        iterations: 4,
        group_size: rt.model.batch.min(4),
        lr: 1e-4,
        sample: SampleCfg { temperature: 1.0, top_p: 1.0, max_new: 4, seed: 5 },
        seed: 5,
        log_every: 2,
    };
    let log = rl_stage(session.engine(), &rt, &mut state, &[Suite::Math500], &cfg).unwrap();
    assert!(log.final_reward >= 0.0);
    assert!(!log.curve.is_empty());
    // the policy update actually advanced the device state
    let sc = state.scalars().unwrap();
    assert_eq!(sc[qadx::runtime::scalar::STEP], cfg.iterations as f32);
    common::cleanup("dst_rl");
}
