//! Backend cross-validation: when real AOT artifacts exist, the PJRT
//! backend (XLA executing the lowered HLO) and the pure-Rust reference
//! backend must agree on golden inputs — forward logits, frontier gather,
//! eval metrics, and a full train-step state update. This turns the
//! reference interpreter into a standing oracle for future backend work
//! (GPU, sharded, remote): any divergence is a bug in one of the two.
#![cfg(feature = "pjrt")]

mod common;

use qadx::coordinator::init_params;
use qadx::runtime::{scalar, BackendKind, Batch, DeviceState, Engine, ModelRuntime};
use qadx::util::rng::Rng;

const MODEL: &str = "size-xs";

fn engines() -> Option<(Engine, Engine)> {
    let dir = match common::real_artifacts_dir() {
        Some(d) => d,
        None => {
            common::artifact_tier_disabled("backend_cross_validation");
            return None;
        }
    };
    let pjrt = Engine::with_backend(&dir, BackendKind::Pjrt).expect("pjrt engine");
    let reference = Engine::with_backend(&dir, BackendKind::Reference).expect("reference engine");
    Some((pjrt, reference))
}

fn golden_batch(rt: &ModelRuntime) -> Batch {
    let mut rng = Rng::new(0x601d);
    let (b, s) = (rt.model.batch, rt.model.seq_len);
    Batch {
        tokens: (0..b * s).map(|_| rng.range(4, rt.model.vocab as i64) as i32).collect(),
        mask: vec![1.0; b * s],
        pixels: None,
        advantage: None,
    }
}

fn max_rel_diff(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let scale = b.iter().fold(0f64, |m, v| m.max(v.abs() as f64)).max(1e-12);
    a.iter()
        .zip(b)
        .fold(0f64, |m, (x, y)| m.max((*x as f64 - *y as f64).abs()))
        / scale
}

#[test]
fn forward_logits_agree_across_backends() {
    let Some((pjrt, refe)) = engines() else { return };
    for fwd_key in ["fwd_bf16", "fwd_nvfp4"] {
        let rt_p = ModelRuntime::new(&pjrt, MODEL).unwrap();
        let rt_r = ModelRuntime::new(&refe, MODEL).unwrap();
        let params = init_params(&rt_p.model, 0);
        let batch = golden_batch(&rt_p);
        let (b, s, v) = (rt_p.model.batch, rt_p.model.seq_len, rt_p.model.vocab);

        let out_p = pjrt
            .run_b(
                &rt_p.exe(fwd_key).unwrap(),
                &[&rt_p.upload_params(&params).unwrap(), &rt_p.upload_tokens(&batch).unwrap()],
            )
            .unwrap();
        let lp = pjrt.download_f32(&out_p, b * s * v).unwrap();
        let out_r = refe
            .run_b(
                &rt_r.exe(fwd_key).unwrap(),
                &[&rt_r.upload_params(&params).unwrap(), &rt_r.upload_tokens(&batch).unwrap()],
            )
            .unwrap();
        let lr_ = refe.download_f32(&out_r, b * s * v).unwrap();
        let d = max_rel_diff(&lr_, &lp);
        assert!(d < 5e-3, "{fwd_key}: backends diverge (max rel diff {d})");
    }
}

#[test]
fn frontier_gather_agrees_across_backends() {
    let Some((pjrt, refe)) = engines() else { return };
    let rt_p = ModelRuntime::new(&pjrt, MODEL).unwrap();
    let rt_r = ModelRuntime::new(&refe, MODEL).unwrap();
    if !rt_p.model.has_artifact("fwd_last_bf16") {
        common::artifact_tier_disabled("frontier_gather_cross (no fwd_last_bf16)");
        return;
    }
    let params = init_params(&rt_p.model, 2);
    let batch = golden_batch(&rt_p);
    let (b, s, v) = (rt_p.model.batch, rt_p.model.seq_len, rt_p.model.vocab);
    let idx: Vec<i32> = (0..b).map(|i| (i % s) as i32).collect();

    let out_p = pjrt
        .run_b(
            &rt_p.exe("fwd_last_bf16").unwrap(),
            &[
                &rt_p.upload_params(&params).unwrap(),
                &rt_p.upload_tokens(&batch).unwrap(),
                &pjrt.upload_i32(&idx, &[b]).unwrap(),
            ],
        )
        .unwrap();
    let lp = pjrt.download_f32(&out_p, b * v).unwrap();
    let out_r = refe
        .run_b(
            &rt_r.exe("fwd_last_bf16").unwrap(),
            &[
                &rt_r.upload_params(&params).unwrap(),
                &rt_r.upload_tokens(&batch).unwrap(),
                &refe.upload_i32(&idx, &[b]).unwrap(),
            ],
        )
        .unwrap();
    let lr_ = refe.download_f32(&out_r, b * v).unwrap();
    let d = max_rel_diff(&lr_, &lp);
    assert!(d < 5e-3, "frontier gather diverges (max rel diff {d})");
}

#[test]
fn eval_metrics_agree_across_backends() {
    let Some((pjrt, refe)) = engines() else { return };
    let rt_p = ModelRuntime::new(&pjrt, MODEL).unwrap();
    let rt_r = ModelRuntime::new(&refe, MODEL).unwrap();
    let student = init_params(&rt_p.model, 1);
    let teacher = init_params(&rt_p.model, 5);
    let batch = golden_batch(&rt_p);

    let run = |engine: &Engine, rt: &ModelRuntime| -> Vec<f32> {
        let out = engine
            .run_b(
                &rt.exe("eval_nvfp4").unwrap(),
                &[
                    &rt.upload_params(&student).unwrap(),
                    &rt.upload_params(&teacher).unwrap(),
                    &rt.upload_tokens(&batch).unwrap(),
                    &rt.upload_mask(&batch).unwrap(),
                ],
            )
            .unwrap();
        engine.download_f32(&out, 8).unwrap()
    };
    let mp = run(&pjrt, &rt_p);
    let mr = run(&refe, &rt_r);
    // kl_mean, ce_mean, token count must agree; sums follow.
    for i in [0usize, 1, 2] {
        let rel = ((mp[i] - mr[i]).abs() as f64) / (mp[i].abs() as f64).max(1e-6);
        assert!(rel < 1e-2, "eval slot {i}: pjrt {} vs reference {}", mp[i], mr[i]);
    }
}

#[test]
fn train_step_state_update_agrees_across_backends() {
    let Some((pjrt, refe)) = engines() else { return };
    let rt_p = ModelRuntime::new(&pjrt, MODEL).unwrap();
    let rt_r = ModelRuntime::new(&refe, MODEL).unwrap();
    let params = init_params(&rt_p.model, 7);
    let batch = golden_batch(&rt_p);
    let lr = 1e-3f32;

    let run = |engine: &Engine, rt: &ModelRuntime| -> (Vec<f32>, Vec<f32>) {
        let mut state = DeviceState::from_params(rt, &params).unwrap();
        let exe = rt.exe("sft_bf16").unwrap();
        let tokens = rt.upload_tokens(&batch).unwrap();
        let mask = rt.upload_mask(&batch).unwrap();
        let lr_buf = engine.upload_scalar(lr).unwrap();
        let out = engine.run_b(&exe, &[&state.buf, &tokens, &mask, &lr_buf]).unwrap();
        state.advance(out);
        (state.scalars().unwrap(), state.params().unwrap())
    };
    let (sc_p, pp) = run(&pjrt, &rt_p);
    let (sc_r, pr) = run(&refe, &rt_r);
    assert_eq!(sc_p[scalar::STEP], sc_r[scalar::STEP]);
    let loss_rel =
        ((sc_p[scalar::LOSS] - sc_r[scalar::LOSS]).abs() as f64) / (sc_p[scalar::LOSS] as f64);
    assert!(loss_rel < 5e-3, "loss diverges: {} vs {}", sc_p[scalar::LOSS], sc_r[scalar::LOSS]);
    // Adam clips per-param updates to ~lr; allow a few lr of drift where
    // tiny gradients flip the moment-normalized sign.
    let max_abs = pp
        .iter()
        .zip(&pr)
        .fold(0f32, |m, (a, b)| m.max((a - b).abs()));
    assert!(max_abs <= 4.0 * lr, "params diverge by {max_abs} (> 4*lr)");
}
