//! Quantized-domain (packed) GEMM kernel tier, end to end.
//!
//! The packed tier computes decode GEMMs directly on the 4-bit packed
//! representation (nibble planes + block scales) instead of the exact
//! tier's re-materialized fake-quantized f32 weights. It is gated by an
//! accuracy budget, not bit-exactness: for every format and block stack,
//! greedy decode must pick the *identical* token sequence and every
//! logit must stay within `PACKED_LOGIT_ATOL/RTOL` of the exact oracle
//! (MXFP4's power-of-two block scales factor out of the dot exactly, so
//! that format is asserted bitwise). The packed binding must also store
//! several times fewer weight bytes — the gauge the serve façade exports
//! as `decode_weight_bytes`.
//!
//! Prompts here are a single token: the exact tier's cold prefill runs
//! the stateless forward, whose joint prompt-activation scale degenerates
//! to the per-row step scale at length 1 — so any divergence beyond the
//! budget is the kernel's fault, never the known prefill scale split.
//!
//! Entirely hermetic: reference backend over synthetic manifests.

mod common;

use qadx::api::{DecodeMode, ServeCfg, ServeWeights};
use qadx::coordinator::init_params;
use qadx::eval::SampleCfg;
use qadx::quant::packed::within_budget;
use qadx::quant::KernelTier;
use qadx::runtime::{DecodeOpts, ModelRuntime, SynthSpec};
use qadx::util::pool;
use qadx::util::rng::Rng;

/// The hybrid stack the packed tier must track: attention + SSM + MoE,
/// d_model 32 so every format's block width divides the contraction dim
/// (MXFP4 needs k % 32 == 0). Declares all three quantized fwd keys.
fn hybrid_spec(name: &str) -> SynthSpec {
    let mut spec = common::small_spec(name);
    spec.d_model = 32;
    spec.n_heads = 2;
    spec.d_ff = 32;
    spec.vocab = 32;
    spec.seq_len = 8;
    spec.blocks = vec!["attn".into(), "ssm".into(), "moe".into()];
    spec.n_experts = 3;
    spec.artifact_keys = vec!["fwd_nvfp4".into(), "fwd_mxfp4".into(), "fwd_int4".into()];
    spec
}

fn argmax(logits: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best
}

fn kernel_opts(tier: KernelTier) -> DecodeOpts {
    DecodeOpts { kernel: Some(tier), ..DecodeOpts::default() }
}

/// Open one exact and one packed session over identical weights, prefill
/// one token, then greedy-decode to capacity in lockstep: same argmax at
/// every position, every packed logit within the accuracy budget, and
/// the packed binding at least 4x smaller than the exact f32 copies.
/// Returns the packed logit rows for cross-run comparisons.
fn assert_packed_matches_exact_greedy(tag: &str, fwd_key: &str) -> Vec<Vec<f32>> {
    let engine = common::reference_engine(tag, &[hybrid_spec("packed-sim")]);
    let rt = ModelRuntime::new(&engine, "packed-sim").unwrap();
    let params = init_params(&rt.model, 31);
    let p_buf = rt.upload_params(&params).unwrap();
    let mut exact = engine
        .open_decode_opts(&rt.model, fwd_key, &p_buf, 1, &kernel_opts(KernelTier::Exact))
        .unwrap()
        .expect("reference backend has stateful decode");
    let mut packed = engine
        .open_decode_opts(&rt.model, fwd_key, &p_buf, 1, &kernel_opts(KernelTier::Packed))
        .unwrap()
        .expect("reference backend has stateful decode");
    let (eb, pb) = (exact.decode_weight_bytes(), packed.decode_weight_bytes());
    assert!(pb > 0, "packed binding must report its storage ({fwd_key})");
    assert!(pb * 4 < eb, "packed {pb}B must be >4x below exact {eb}B ({fwd_key})");

    let mut rb = Rng::new(31 ^ 0x77);
    let mut tok = rb.range(1, rt.model.vocab as i64) as i32;
    let (mut le, mut lp) = (Vec::new(), Vec::new());
    exact.prefill(0, &[tok], &mut le).unwrap();
    packed.prefill(0, &[tok], &mut lp).unwrap();
    let mut rows = Vec::new();
    for pos in 1..rt.model.seq_len {
        let ea = argmax(&le);
        assert_eq!(
            argmax(&lp),
            ea,
            "greedy token diverged at position {pos} ({fwd_key}, {tag})"
        );
        for (j, (&got, &want)) in lp.iter().zip(&le).enumerate() {
            assert!(
                within_budget(got, want),
                "logit {j} off budget at position {pos} ({fwd_key}): {got} vs {want}"
            );
        }
        rows.push(lp.clone());
        tok = ea as i32;
        exact.step(0, tok, &mut le).unwrap();
        packed.step(0, tok, &mut lp).unwrap();
    }
    assert_eq!(argmax(&lp), argmax(&le), "final greedy token diverged ({fwd_key})");
    rows.push(lp.clone());
    common::cleanup(tag);
    rows
}

#[test]
fn packed_matches_exact_greedy_nvfp4() {
    assert_packed_matches_exact_greedy("packed_e2e_nvfp4", "fwd_nvfp4");
}

#[test]
fn packed_matches_exact_greedy_mxfp4() {
    // power-of-two block scales factor out of the dot exactly, so the
    // packed MXFP4 kernel is bitwise-identical, not merely within budget
    let engine = common::reference_engine("packed_e2e_mxfp4", &[hybrid_spec("packed-sim")]);
    let rt = ModelRuntime::new(&engine, "packed-sim").unwrap();
    let params = init_params(&rt.model, 31);
    let p_buf = rt.upload_params(&params).unwrap();
    let mut exact = engine
        .open_decode_opts(&rt.model, "fwd_mxfp4", &p_buf, 1, &kernel_opts(KernelTier::Exact))
        .unwrap()
        .unwrap();
    let mut packed = engine
        .open_decode_opts(&rt.model, "fwd_mxfp4", &p_buf, 1, &kernel_opts(KernelTier::Packed))
        .unwrap()
        .unwrap();
    let mut rb = Rng::new(31 ^ 0x77);
    let mut tok = rb.range(1, rt.model.vocab as i64) as i32;
    let (mut le, mut lp) = (Vec::new(), Vec::new());
    exact.prefill(0, &[tok], &mut le).unwrap();
    packed.prefill(0, &[tok], &mut lp).unwrap();
    for pos in 1..rt.model.seq_len {
        for (j, (&got, &want)) in lp.iter().zip(&le).enumerate() {
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "mxfp4 logit {j} not bitwise at position {pos}: {got} vs {want}"
            );
        }
        tok = argmax(&le) as i32;
        exact.step(0, tok, &mut le).unwrap();
        packed.step(0, tok, &mut lp).unwrap();
    }
    common::cleanup("packed_e2e_mxfp4");
}

#[test]
fn packed_matches_exact_greedy_int4() {
    assert_packed_matches_exact_greedy("packed_e2e_int4", "fwd_int4");
}

#[test]
fn packed_logits_are_thread_count_invariant_e2e() {
    let one = pool::with_threads(1, || {
        assert_packed_matches_exact_greedy("packed_e2e_t1", "fwd_nvfp4")
    });
    let four = pool::with_threads(4, || {
        assert_packed_matches_exact_greedy("packed_e2e_t4", "fwd_nvfp4")
    });
    assert_eq!(one.len(), four.len());
    for (pos, (a, b)) in one.iter().zip(&four).enumerate() {
        for (j, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "packed logit {j} at position {pos} changed with thread count"
            );
        }
    }
}

#[test]
fn serve_reports_decode_weight_bytes_and_packed_shrinks_it() {
    let tag = "packed_serve_gauge";
    let session = common::reference_session(tag, &[hybrid_spec("packed-sim")]);
    let ms = session.model("packed-sim").unwrap();
    let cfg_for = |kernel| ServeCfg {
        sample: SampleCfg { temperature: 0.7, top_p: 0.9, max_new: 4, seed: 9 },
        weights: ServeWeights::Random { seed: 21 },
        decode: DecodeMode::Step,
        max_slots: 2,
        kernel,
        ..ServeCfg::default()
    };
    let mut exact = ms.server("fwd_nvfp4", &cfg_for(Some(KernelTier::Exact))).unwrap();
    let mut packed = ms.server("fwd_nvfp4", &cfg_for(Some(KernelTier::Packed))).unwrap();
    let (eb, pb) = (exact.stats().decode_weight_bytes, packed.stats().decode_weight_bytes);
    assert!(eb > 0, "exact tier must report its bound f32 weight bytes");
    assert!(pb > 0 && pb * 4 < eb, "packed {pb}B must be >4x below exact {eb}B");
    assert!(
        packed.stats().summary().contains("w-bytes"),
        "summary must print the gauge: {}",
        packed.stats().summary()
    );
    // the gauge survives a served request (sync_paged refreshes it)
    for server in [&mut exact, &mut packed] {
        server.submit(vec![1, 5, 3]).unwrap();
        server.drain().unwrap();
    }
    assert_eq!(packed.stats().decode_weight_bytes, pb);
    assert_eq!(exact.stats().decode_weight_bytes, eb);
    common::cleanup(tag);
}
