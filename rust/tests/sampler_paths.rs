//! Decode-path equivalence: the frontier-gather (`fwd_last_*`) artifact and
//! the full-logits download must produce identical rows for a fixed seed —
//! the gather changes how logits reach the host, never what gets sampled.
//! Requires `make artifacts` (skipped with a clear message otherwise).

use std::path::Path;

use qadx::coordinator::init_params;
use qadx::eval::{SampleCfg, Sampler};
use qadx::runtime::{frontier_key, Engine, ModelRuntime};

fn engine() -> Option<Engine> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::new(&dir).expect("engine"))
}

#[test]
fn frontier_key_mapping() {
    assert_eq!(frontier_key("fwd_bf16").as_deref(), Some("fwd_last_bf16"));
    assert_eq!(frontier_key("fwd_nvfp4").as_deref(), Some("fwd_last_nvfp4"));
    assert_eq!(
        frontier_key("fwd_bf16_state").as_deref(),
        Some("fwd_last_bf16_state")
    );
    assert_eq!(frontier_key("sft_bf16"), None);
    assert_eq!(frontier_key("scalars"), None);
    // already-frontier keys must not double-map
    assert_eq!(frontier_key("fwd_last_bf16"), None);
}

#[test]
fn frontier_and_full_download_rows_identical() {
    let Some(engine) = engine() else { return };
    let rt = ModelRuntime::new(&engine, "size-xs").unwrap();
    let params = init_params(&rt.model, 0);
    let p_buf = rt.upload_params(&params).unwrap();
    let prompts: Vec<Vec<i32>> = (0..rt.model.batch.min(4))
        .map(|i| vec![1, 4 + i as i32, 7, 3])
        .collect();
    let cfg = SampleCfg { temperature: 0.6, top_p: 0.95, max_new: 6, seed: 42 };

    let mut fast = Sampler::new(&rt, "fwd_bf16", cfg).unwrap();
    if !fast.uses_frontier() {
        eprintln!("skipping: manifest has no fwd_last_bf16 (rebuild artifacts)");
        return;
    }
    let mut full = Sampler::new(&rt, "fwd_bf16", cfg).unwrap();
    full.force_full_logits(true);
    assert!(!full.uses_frontier());

    let rows_fast = fast.generate(&engine, &p_buf, &prompts, None).unwrap();
    let rows_full = full.generate(&engine, &p_buf, &prompts, None).unwrap();
    assert_eq!(rows_fast, rows_full, "decode paths diverged");

    // greedy decode must agree as well (argmax is download-order invariant)
    let greedy = SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 6, seed: 7 };
    let mut fast_g = Sampler::new(&rt, "fwd_bf16", greedy).unwrap();
    let mut full_g = Sampler::new(&rt, "fwd_bf16", greedy).unwrap();
    full_g.force_full_logits(true);
    let a = fast_g.generate(&engine, &p_buf, &prompts, None).unwrap();
    let b = full_g.generate(&engine, &p_buf, &prompts, None).unwrap();
    assert_eq!(a, b, "greedy decode paths diverged");
}
