//! Decode-path equivalence: the frontier-gather (`fwd_last_*`) path and
//! the full-logits download must produce identical rows for a fixed seed —
//! the gather changes how logits reach the host, never what gets sampled.
//!
//! Hermetic tier runs on the reference backend over a synthetic manifest
//! (always, everywhere); the artifact tier repeats the check against real
//! AOT artifacts when they exist.

//! (The stateful prefill/step path has its own equivalence suite in
//! tests/decode_equivalence.rs; here every sampler is pinned to
//! `DecodeMode::Full` so the frontier-vs-full contract is what actually
//! runs, even on backends with stateful decode.)

mod common;

use qadx::coordinator::init_params;
use qadx::eval::{DecodeMode, SampleCfg, Sampler};
use qadx::runtime::{frontier_key, Engine, ModelRuntime};

#[test]
fn frontier_key_mapping() {
    assert_eq!(frontier_key("fwd_bf16").as_deref(), Some("fwd_last_bf16"));
    assert_eq!(frontier_key("fwd_nvfp4").as_deref(), Some("fwd_last_nvfp4"));
    assert_eq!(
        frontier_key("fwd_bf16_state").as_deref(),
        Some("fwd_last_bf16_state")
    );
    assert_eq!(frontier_key("sft_bf16"), None);
    assert_eq!(frontier_key("scalars"), None);
    // already-frontier keys must not double-map
    assert_eq!(frontier_key("fwd_last_bf16"), None);
}

fn assert_frontier_and_full_rows_identical(engine: &Engine, model: &str) {
    let rt = ModelRuntime::new(engine, model).unwrap();
    let params = init_params(&rt.model, 0);
    let p_buf = rt.upload_params(&params).unwrap();
    let prompts: Vec<Vec<i32>> = (0..rt.model.batch.min(4))
        .map(|i| vec![1, 4 + i as i32, 7, 3])
        .collect();
    let cfg = SampleCfg { temperature: 0.6, top_p: 0.95, max_new: 6, seed: 42 };

    let mut fast = Sampler::new(&rt, "fwd_bf16", cfg).unwrap();
    fast.set_decode_mode(DecodeMode::Full);
    assert!(
        fast.uses_frontier(),
        "manifest carries fwd_last_bf16 but the sampler did not pick it up"
    );
    let mut full = Sampler::new(&rt, "fwd_bf16", cfg).unwrap();
    full.set_decode_mode(DecodeMode::Full);
    full.force_full_logits(true);
    assert!(!full.uses_frontier());

    let rows_fast = fast.generate(engine, &p_buf, &prompts, None).unwrap();
    let rows_full = full.generate(engine, &p_buf, &prompts, None).unwrap();
    assert_eq!(rows_fast, rows_full, "decode paths diverged");

    // greedy decode must agree as well (argmax is download-order invariant)
    let greedy = SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 6, seed: 7 };
    let mut fast_g = Sampler::new(&rt, "fwd_bf16", greedy).unwrap();
    fast_g.set_decode_mode(DecodeMode::Full);
    let mut full_g = Sampler::new(&rt, "fwd_bf16", greedy).unwrap();
    full_g.set_decode_mode(DecodeMode::Full);
    full_g.force_full_logits(true);
    let a = fast_g.generate(engine, &p_buf, &prompts, None).unwrap();
    let b = full_g.generate(engine, &p_buf, &prompts, None).unwrap();
    assert_eq!(a, b, "greedy decode paths diverged");
}

// --- hermetic tier ---------------------------------------------------------

#[test]
fn frontier_and_full_download_rows_identical() {
    let engine = common::reference_engine("sampler_eq", &[common::small_spec("size-dec")]);
    assert_frontier_and_full_rows_identical(&engine, "size-dec");
    common::cleanup("sampler_eq");
}

#[test]
fn quantized_decode_paths_agree_too() {
    let engine = common::reference_engine("sampler_eq_q", &[common::small_spec("size-decq")]);
    let rt = ModelRuntime::new(&engine, "size-decq").unwrap();
    let params = init_params(&rt.model, 3);
    let p_buf = rt.upload_params(&params).unwrap();
    let prompts: Vec<Vec<i32>> = vec![vec![1, 9, 3], vec![1, 12, 17, 3]];
    let cfg = SampleCfg { temperature: 0.8, top_p: 0.9, max_new: 5, seed: 11 };
    let mut fast = Sampler::new(&rt, "fwd_nvfp4", cfg).unwrap();
    fast.set_decode_mode(DecodeMode::Full);
    assert!(fast.uses_frontier());
    let mut full = Sampler::new(&rt, "fwd_nvfp4", cfg).unwrap();
    full.set_decode_mode(DecodeMode::Full);
    full.force_full_logits(true);
    let a = fast.generate(&engine, &p_buf, &prompts, None).unwrap();
    let b = full.generate(&engine, &p_buf, &prompts, None).unwrap();
    assert_eq!(a, b, "quantized decode paths diverged");
    common::cleanup("sampler_eq_q");
}

#[test]
fn frontier_fallback_when_manifest_lacks_twin() {
    // A manifest without fwd_last_* keys: generation still works through
    // the full-logits path and reports uses_frontier() == false.
    let mut spec = common::small_spec("size-nolast");
    spec.artifact_keys.retain(|k| !k.starts_with("fwd_last_"));
    let engine = common::reference_engine("sampler_fb", &[spec]);
    let rt = ModelRuntime::new(&engine, "size-nolast").unwrap();
    let params = init_params(&rt.model, 1);
    let p_buf = rt.upload_params(&params).unwrap();
    let cfg = SampleCfg { temperature: 0.6, top_p: 0.95, max_new: 4, seed: 2 };
    let mut s = Sampler::new(&rt, "fwd_bf16", cfg).unwrap();
    s.set_decode_mode(DecodeMode::Full);
    assert!(!s.uses_frontier());
    let rows = s.generate(&engine, &p_buf, &[vec![1, 5, 3]], None).unwrap();
    assert_eq!(rows.len(), 1);
    assert_eq!(rows[0].len(), rt.model.seq_len);
    common::cleanup("sampler_fb");
}

// --- artifact tier ---------------------------------------------------------

#[test]
fn frontier_and_full_download_rows_identical_artifact_tier() {
    let Some(dir) = common::real_artifacts_dir() else {
        common::artifact_tier_disabled("frontier_vs_full");
        return;
    };
    let engine = Engine::new(&dir).expect("engine");
    assert_frontier_and_full_rows_identical(&engine, "size-xs");
}
