//! Reference-backend compute-core benchmarks: the blocked/parallel GEMM
//! family, the hermetic full forward, the QAD train step, and decode
//! throughput (tokens/sec) through the reference engine — including a
//! long-context seq-len sweep comparing the stateful prefill/step decode
//! against the stateless full-forward path (step per-token time stays
//! ~flat in seq_len; full grows with it). Entirely hermetic — a
//! synthetic manifest, no artifacts, no XLA.
//!
//! `cargo bench --bench refgemm_bench` → BENCH_refgemm.json at the repo
//! root (the committed file carries a `baseline` section with the pre-PR
//! single-thread naive numbers, so `scripts/bench_diff.py
//! BENCH_refgemm.json --against-baseline` tracks the speedup).
//! `QADX_THREADS` / `--threads` size the pool; `_t1` rows pin one thread
//! for an on-machine scaling reference.

use qadx::quant::packed::{self, KernelTier, PackedFormat, PackedWeight};
use qadx::runtime::refmodel::{self, LossKind, RefCfg};
use qadx::runtime::{
    synthetic_manifest_json, BackendKind, DecodeOpts, Engine, ModelRuntime, SynthSpec,
};
use qadx::util::bench::BenchSuite;
use qadx::util::rng::Rng;
use qadx::util::{gemm, pool};

fn randn(n: usize, seed: u64, scale: f32) -> Vec<f32> {
    let mut r = Rng::new(seed);
    (0..n).map(|_| r.normal() as f32 * scale).collect()
}

/// The bench model: big enough that every GEMM crosses the parallel
/// threshold, small enough to iterate quickly.
fn bench_spec() -> SynthSpec {
    let mut spec = SynthSpec::small("refgemm-bench");
    spec.d_model = 128;
    spec.n_heads = 4;
    spec.d_ff = 256;
    spec.vocab = 512;
    spec.seq_len = 32;
    spec.batch = 4;
    spec
}

/// Init params like the reference tests: ln scales 1, biases 0, fan-in
/// scaled normals elsewhere.
fn init_params(cfg: &RefCfg, seed: u64) -> Vec<f32> {
    let mut r = Rng::new(seed);
    let mut p = vec![0f32; cfg.model.param_count];
    for d in &cfg.model.params {
        let leaf = d.name.rsplit('.').next().unwrap_or("");
        let slice = &mut p[d.offset..d.offset + d.size];
        if leaf.starts_with("ln") {
            slice.fill(1.0);
        } else if leaf == "a_bias" || leaf == "vis_bias" {
            slice.fill(0.0);
        } else {
            let fan_in =
                if d.shape.len() >= 2 { d.shape[d.shape.len() - 2] } else { d.shape[0] };
            let std = 1.0 / (fan_in as f32).sqrt();
            for v in slice.iter_mut() {
                *v = r.normal() as f32 * std;
            }
        }
    }
    p
}

fn main() {
    let mut suite = BenchSuite::new("refgemm");
    println!("pool threads: {}", pool::threads());

    // ---- GEMM family, 256^3 ------------------------------------------
    let n = 256usize;
    let a = randn(n * n, 1, 1.0);
    let b = randn(n * n, 2, 1.0);
    suite.run("gemm_matmul_256x256x256", 3, 30, || {
        std::hint::black_box(gemm::matmul(&a, &b, n, n, n));
    });
    suite.run("gemm_matmul_256x256x256_t1", 3, 30, || {
        pool::with_threads(1, || {
            std::hint::black_box(gemm::matmul(&a, &b, n, n, n));
        });
    });
    suite.run("gemm_matmul_tn_256x256x256", 3, 30, || {
        std::hint::black_box(gemm::matmul_tn(&a, &b, n, n, n));
    });
    suite.run("gemm_matmul_nt_256x256x256", 3, 30, || {
        std::hint::black_box(gemm::matmul_nt(&a, &b, n, n, n));
    });

    // ---- packed quantized-domain micro-kernels -----------------------
    // One decode-shaped matvec per packed format: LUT dot products over
    // nibble planes + block scales, against the 256x256 f32 GEMM family
    // above for the traffic/compute comparison.
    let wq = randn(n * n, 5, 0.05);
    let xq = randn(n, 6, 1.0);
    for (fmt, label) in [
        (PackedFormat::Nvfp4, "nvfp4"),
        (PackedFormat::Mxfp4, "mxfp4"),
        (PackedFormat::Int4, "int4"),
    ] {
        let pw = PackedWeight::pack(&wq, n, n, fmt).expect("pack");
        let mut out = vec![0f32; n];
        let name = format!("packed_matvec_{label}_256x256");
        suite.run(&name, 3, 200, || {
            pw.matvec_into(&xq, &mut out).expect("packed matvec");
            std::hint::black_box(&out);
        });
    }

    // ---- hermetic full forward / train step --------------------------
    let spec = bench_spec();
    let entry = spec.entry();
    let cfg = RefCfg::for_key_format(&entry, "nvfp4").expect("nvfp4 cfg");
    let teacher_cfg = RefCfg::bf16(&entry);
    let params = init_params(&cfg, 11);
    let m = cfg.model.clone();
    let mut rng = Rng::new(13);
    let tokens: Vec<i32> =
        (0..m.batch * m.seq_len).map(|_| rng.range(1, m.vocab as i64) as i32).collect();
    let mask = vec![1f32; m.batch * m.seq_len];

    suite.run("ref_full_forward_nvfp4_d128_b4s32", 2, 12, || {
        std::hint::black_box(
            refmodel::fwd_logits(&cfg, &params, &tokens, m.batch, m.seq_len, None).unwrap(),
        );
    });

    let mut state = vec![0f32; 3 * m.param_count + 8];
    state[..m.param_count].copy_from_slice(&params);
    suite.run("ref_train_step_qad_d128_b4s32", 1, 8, || {
        let out = refmodel::train_step(
            &cfg,
            Some((&teacher_cfg, &params)),
            &LossKind::Kl,
            false,
            &state,
            &tokens,
            &mask,
            m.batch,
            m.seq_len,
            1e-3,
            None,
            None,
            8,
        )
        .unwrap();
        std::hint::black_box(out);
    });

    // ---- decode tokens/sec through the reference engine --------------
    // One manifest carries the bench model plus long-context variants for
    // the seq-len sweep.
    let mut specs = vec![spec];
    for s in [64usize, 256] {
        let mut long = bench_spec();
        long.name = format!("refgemm-bench-s{s}");
        long.seq_len = s;
        specs.push(long);
    }
    let dir = std::env::temp_dir().join(format!("qadx_refgemm_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("bench tmp dir");
    std::fs::write(dir.join("manifest.json"), synthetic_manifest_json(&specs))
        .expect("write manifest");
    let engine =
        Engine::with_backend(&dir, BackendKind::Reference).expect("reference engine");
    {
        let rt = ModelRuntime::new(&engine, "refgemm-bench").expect("model runtime");
        let sample = qadx::eval::SampleCfg { temperature: 0.6, top_p: 0.95, max_new: 12, seed: 7 };
        // default decode (stateful prefill/step on the reference backend)
        let mut sampler = qadx::eval::Sampler::new(&rt, "fwd_nvfp4", sample).expect("sampler");
        let wbuf = engine.upload_f32(&params, &[params.len()]).expect("weights");
        let prompts: Vec<Vec<i32>> =
            (0..m.batch).map(|i| vec![2 + i as i32, 3, 4, 5]).collect();
        // nominal decode work per call (rows may stop early at EOS)
        let units = (m.batch * sample.max_new) as f64;
        suite.run_units("ref_decode_nvfp4_b4_new12_toks", 1, 10, units, || {
            std::hint::black_box(
                sampler.generate(&engine, &wbuf, &prompts, None).expect("generate"),
            );
        });

        // the same decode schedule on the packed quantized-domain kernel
        // tier: GEMMs run on 4-bit codes + block scales instead of
        // re-materialized fake-quant f32 weights (process-global toggle —
        // the sampler opens its decode session under it)
        packed::set_kernel(KernelTier::Packed);
        let mut sampler =
            qadx::eval::Sampler::new(&rt, "fwd_nvfp4", sample).expect("packed sampler");
        suite.run_units("ref_decode_packed_nvfp4_b4_new12_toks", 1, 10, units, || {
            std::hint::black_box(
                sampler.generate(&engine, &wbuf, &prompts, None).expect("generate"),
            );
        });
        packed::clear_kernel();

        // long-context sweep with a fixed short prompt: the full path
        // re-forwards the whole (B, S) artifact per token, so its
        // per-token time grows with seq_len; the step path works at the
        // frontier and stays ~flat. A final long-prompt row isolates the
        // prefill-dominated regime (prompt ≈ S) on the step path.
        for (model_name, s, prompt_len, iters, modes) in [
            ("refgemm-bench-s64", 64usize, 4usize, 6usize, &["step", "full"][..]),
            ("refgemm-bench-s256", 256, 4, 3, &["step", "full"][..]),
            ("refgemm-bench-s256", 256, 240, 3, &["step"][..]),
        ] {
            let rt = ModelRuntime::new(&engine, model_name).expect("sweep runtime");
            let cfg_s = RefCfg::for_key_format(&rt.model, "nvfp4").expect("sweep cfg");
            let sweep_params = init_params(&cfg_s, 11);
            let wbuf = engine
                .upload_f32(&sweep_params, &[sweep_params.len()])
                .expect("sweep weights");
            let prompts: Vec<Vec<i32>> = (0..rt.model.batch)
                .map(|i| (0..prompt_len).map(|j| 2 + ((i * 7 + j) % 300) as i32).collect())
                .collect();
            let units = (rt.model.batch * sample.max_new) as f64;
            for &label in modes {
                let mode = if label == "step" {
                    qadx::eval::DecodeMode::Step
                } else {
                    qadx::eval::DecodeMode::Full
                };
                let mut sampler =
                    qadx::eval::Sampler::new(&rt, "fwd_nvfp4", sample).expect("sweep sampler");
                sampler.set_decode_mode(mode);
                let name = format!("ref_decode_{label}_nvfp4_s{s}_p{prompt_len}_toks");
                suite.run_units(&name, 1, iters, units, || {
                    std::hint::black_box(
                        sampler.generate(&engine, &wbuf, &prompts, None).expect("generate"),
                    );
                });
            }
        }
    }

    // ---- paged decode state & prefix reuse ---------------------------
    // TTFT over a 192-token shared prefix on the s256 model: the cold row
    // pays the full O(prompt) prefill every call; the hit row forks
    // refcounted pages out of the prefix cache and returns the stored
    // logits without replaying anything. The budget row pins `max_pages`
    // to the live-token demand (224 pages vs the 256 page-equivalents a
    // dense rows x seq_len layout reserves up front) and runs a full
    // prefill + 12-step decode for every row inside that bound.
    {
        let rt = ModelRuntime::new(&engine, "refgemm-bench-s256").expect("paged runtime");
        let cfg_p = RefCfg::for_key_format(&rt.model, "nvfp4").expect("paged cfg");
        let pp = init_params(&cfg_p, 11);
        let wbuf = engine.upload_f32(&pp, &[pp.len()]).expect("paged weights");
        let rows = rt.model.batch;
        let prefix: Vec<i32> = (0..192).map(|j| 2 + (j % 300) as i32).collect();
        let mut logits: Vec<f32> = Vec::new();

        let cold = DecodeOpts { page_size: 16, prefix_cache: 0, max_pages: 0, kernel: None };
        let mut sess = engine
            .open_decode_opts(&rt.model, "fwd_nvfp4", &wbuf, rows, &cold)
            .expect("open paged session")
            .expect("reference backend has stateful decode");
        suite.run("ref_prefill_cold_paged16_nvfp4_s256_p192", 1, 6, || {
            sess.prefill(0, &prefix, &mut logits).expect("cold prefill");
            std::hint::black_box(&logits);
            sess.close(0).expect("close cold row");
        });

        let hit = DecodeOpts { page_size: 16, prefix_cache: 4, max_pages: 0, kernel: None };
        let mut sess = engine
            .open_decode_opts(&rt.model, "fwd_nvfp4", &wbuf, rows, &hit)
            .expect("open cached session")
            .expect("reference backend has stateful decode");
        sess.prefill(0, &prefix, &mut logits).expect("warm prefill");
        sess.close(0).expect("close warm row");
        suite.run("ref_prefill_hit_paged16_nvfp4_s256_p192", 1, 30, || {
            sess.prefill(0, &prefix, &mut logits).expect("hit prefill");
            std::hint::black_box(&logits);
            sess.close(0).expect("close hit row");
        });
        let ps = sess.paged_stats().expect("paged stats");
        println!("prefix cache: {} hits / {} misses", ps.prefix_hits, ps.prefix_misses);

        let budget = DecodeOpts { page_size: 16, prefix_cache: 0, max_pages: 224, kernel: None };
        let mut sess = engine
            .open_decode_opts(&rt.model, "fwd_nvfp4", &wbuf, rows, &budget)
            .expect("open budgeted session")
            .expect("reference backend has stateful decode");
        let row_prompts: Vec<Vec<i32>> = (0..rows)
            .map(|r| (0..192).map(|j| 2 + ((r * 7 + j) % 300) as i32).collect())
            .collect();
        let new_toks = 12usize;
        let units = (rows * new_toks) as f64;
        suite.run_units("ref_decode_paged16_budget224_nvfp4_s256_toks", 1, 3, units, || {
            for (r, p) in row_prompts.iter().enumerate() {
                sess.prefill(r, p, &mut logits).expect("budget prefill");
            }
            for _ in 0..new_toks {
                for r in 0..rows {
                    sess.step(r, 9, &mut logits).expect("budget step");
                }
            }
            for r in 0..rows {
                sess.close(r).expect("close budget row");
            }
            std::hint::black_box(&logits);
        });
    }
    std::fs::remove_dir_all(&dir).ok();

    suite.finish();
}
