//! Runtime-layer benchmarks: per-step latency of every AOT artifact kind on
//! the PJRT CPU client — the numbers that dominate every table's wall
//! clock. `cargo bench --bench runtime_bench`. CSV: runs/bench/runtime.csv;
//! JSON: BENCH_runtime.json at the repo root.

use qadx::api::Session;
use qadx::coordinator::init_params;
use qadx::data::{shape_for, BatchFactory, SourceSpec, TEXT_SUITES};
use qadx::runtime::DeviceState;
use qadx::util::bench::BenchSuite;

fn main() {
    let Ok(session) = Session::builder().artifacts_dir("artifacts").build() else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    };
    let engine = session.engine();
    let mut suite = BenchSuite::new("runtime");

    for model in ["ace-sim", "nano-sim", "nano3-sim", "super-sim"] {
        let ms = session.model(model).unwrap();
        let rt = &ms.rt;
        let params = init_params(&rt.model, 0);
        let p_buf = rt.upload_params(&params).unwrap();
        let mut factory =
            BatchFactory::new(shape_for(&rt.model), vec![SourceSpec::sft(TEXT_SUITES)], 7);
        let batch = factory.next_batch(None).unwrap();
        let tokens = rt.upload_tokens(&batch).unwrap();
        let mask = rt.upload_mask(&batch).unwrap();
        let lr = engine.upload_scalar(1e-4).unwrap();

        // forward passes
        for key in ["fwd_bf16", "fwd_nvfp4"] {
            let exe = rt.exe(key).unwrap();
            suite.run(&format!("{model}/{key}"), 2, 15, || {
                std::hint::black_box(engine.run_b(&exe, &[&p_buf, &tokens]).unwrap());
            });
        }
        // frontier-gather twins: fused fwd + per-row logits slice (B·V out)
        let frontier: Vec<i32> = vec![(rt.model.seq_len - 1) as i32; rt.model.batch];
        for key in ["fwd_last_bf16", "fwd_last_nvfp4"] {
            if !rt.model.has_artifact(key) {
                continue; // older artifact build
            }
            let exe = rt.exe(key).unwrap();
            let idx_buf = engine.upload_i32(&frontier, &[rt.model.batch]).unwrap();
            suite.run(&format!("{model}/{key}"), 2, 15, || {
                std::hint::black_box(
                    engine.run_b(&exe, &[&p_buf, &tokens, &idx_buf]).unwrap(),
                );
            });
        }
        // training steps (device-resident state chain)
        let mut state = DeviceState::from_params(rt, &params).unwrap();
        for key in ["sft_bf16", "qat_nvfp4", "qad_nvfp4"] {
            let exe = rt.exe(key).unwrap();
            let needs_teacher = rt
                .model
                .artifact(key)
                .unwrap()
                .args
                .iter()
                .any(|a| a.name == "teacher_params");
            suite.run(&format!("{model}/{key}"), 2, 10, || {
                let out = if needs_teacher {
                    engine
                        .run_b(&exe, &[&state.buf, &p_buf, &tokens, &mask, &lr])
                        .unwrap()
                } else {
                    engine.run_b(&exe, &[&state.buf, &tokens, &mask, &lr]).unwrap()
                };
                state.advance(out);
            });
        }
        // metrics readback
        suite.run(&format!("{model}/scalars_readback"), 2, 30, || {
            std::hint::black_box(state.scalars().unwrap());
        });
        // host upload cost of a batch
        suite.run(&format!("{model}/batch_upload"), 2, 30, || {
            std::hint::black_box(rt.upload_tokens(&batch).unwrap());
            std::hint::black_box(rt.upload_mask(&batch).unwrap());
        });
    }
    suite.finish();
}
