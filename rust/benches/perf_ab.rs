//! §Perf A/B benchmarks — the hot-path design decisions, measured:
//!
//!   A. training-state placement: device-resident `execute_b` chaining
//!      (ours) vs re-uploading the state vector every step (naive)
//!   B. per-step metrics: 8-float device-side `scalars` artifact (ours)
//!      vs downloading the full state and slicing on host (naive)
//!   C. fwd precision paths: fwd_bf16 vs fwd_nvfp4 (fake-quant overhead on
//!      CPU — on Blackwell this inverts; see DESIGN.md §Perf)
//!   D. sampler decode paths: frontier-gather (`fwd_last`, B·V floats per
//!      emitted token) vs the naive full-logits download (B·S·V)
//!
//! `cargo bench --bench perf_ab`; CSV: runs/bench/perf_ab.csv.

use qadx::api::Session;
use qadx::coordinator::init_params;
use qadx::data::{shape_for, BatchFactory, SourceSpec, TEXT_SUITES};
use qadx::eval::{SampleCfg, Sampler};
use qadx::runtime::DeviceState;
use qadx::util::bench::BenchSuite;

fn main() {
    let Ok(session) = Session::builder().artifacts_dir("artifacts").build() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let engine = session.engine();
    let mut suite = BenchSuite::new("perf_ab");
    let model = std::env::var("QADX_PERF_MODEL").unwrap_or_else(|_| "ace-sim".into());
    let ms = session.model(&model).unwrap();
    let rt = &ms.rt;
    let params = init_params(&rt.model, 0);
    let mut factory =
        BatchFactory::new(shape_for(&rt.model), vec![SourceSpec::sft(TEXT_SUITES)], 7);
    let batch = factory.next_batch(None).unwrap();
    let tokens = rt.upload_tokens(&batch).unwrap();
    let mask = rt.upload_mask(&batch).unwrap();
    let lr = engine.upload_scalar(1e-4).unwrap();
    let exe = rt.exe("sft_bf16").unwrap();

    // --- A: state placement ------------------------------------------------
    let mut state = DeviceState::from_params(rt, &params).unwrap();
    suite.run(&format!("{model}/A1_step_device_resident"), 3, 15, || {
        let out = engine.run_b(&exe, &[&state.buf, &tokens, &mask, &lr]).unwrap();
        state.advance(out);
    });
    let mut host_state = state.full().unwrap();
    suite.run(&format!("{model}/A2_step_host_roundtrip"), 3, 15, || {
        // naive: upload state, step, download the whole new state
        let s = DeviceState::from_state_vec(rt, &host_state).unwrap();
        let out = engine.run_b(&exe, &[&s.buf, &tokens, &mask, &lr]).unwrap();
        host_state = s.like(out).full().unwrap();
    });

    // --- B: metrics readback -----------------------------------------------
    suite.run(&format!("{model}/B1_metrics_scalars_artifact"), 3, 30, || {
        std::hint::black_box(state.scalars().unwrap());
    });
    suite.run(&format!("{model}/B2_metrics_full_state_download"), 3, 30, || {
        let full = state.full().unwrap();
        std::hint::black_box(full[full.len() - 8..].to_vec());
    });

    // --- C: fwd precision --------------------------------------------------
    let p_buf = rt.upload_params(&params).unwrap();
    for key in ["fwd_bf16", "fwd_nvfp4"] {
        let fwd = rt.exe(key).unwrap();
        suite.run(&format!("{model}/C_{key}"), 3, 20, || {
            std::hint::black_box(engine.run_b(&fwd, &[&p_buf, &tokens]).unwrap());
        });
    }

    // --- D: sampler decode paths -------------------------------------------
    let mut sampler = Sampler::new(rt, "fwd_bf16", SampleCfg::default()).unwrap();
    println!(
        "{model}: frontier-gather decode {}",
        if sampler.uses_frontier() { "available" } else { "absent (full download)" }
    );
    let prompts: Vec<Vec<i32>> = (0..rt.model.batch)
        .map(|i| vec![1, 4 + (i as i32 % 10), 40, 4, 43, 3])
        .collect();
    suite.run(&format!("{model}/D_generate_batch_12tok"), 2, 8, || {
        std::hint::black_box(sampler.generate(engine, &p_buf, &prompts, None).unwrap());
    });
    if sampler.uses_frontier() {
        // naive path for comparison: full B·S·V logits download per token
        // (pinned to the stateless decode mode so the label stays true on
        // backends with stateful prefill/step decode)
        let mut sampler_full = Sampler::new(rt, "fwd_bf16", SampleCfg::default()).unwrap();
        sampler_full.set_decode_mode(qadx::eval::DecodeMode::Full);
        sampler_full.force_full_logits(true);
        suite.run(&format!("{model}/D2_generate_full_download_12tok"), 2, 8, || {
            std::hint::black_box(
                sampler_full.generate(engine, &p_buf, &prompts, None).unwrap(),
            );
        });
    }

    suite.finish();
}
