//! End-to-end per-table benchmarks: times one reduced-budget run of every
//! paper-table driver (teachers come from the runs/teachers cache, so this
//! measures the recovery + evaluation pipeline — the part each table
//! re-executes). `cargo bench --bench table_bench`.
//!
//! Budget knobs come from env (QADX_BENCH_STEPS / _N / _K) so the §Perf
//! pass can compare like-for-like across optimization iterations;
//! QADX_BENCH_SMOKE=1 clamps to 1 warmup / 1 iter (CI bit-rot guard).
//! CSV: runs/bench/tables.csv; JSON: BENCH_tables.json at the repo root.

use std::path::Path;

use qadx::exper::{self, common::Ctx};
use qadx::util::args::Args;
use qadx::util::bench::BenchSuite;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let steps = env_usize("QADX_BENCH_STEPS", 20);
    let n = env_usize("QADX_BENCH_N", 8);
    let k = env_usize("QADX_BENCH_K", 1);
    let argv: Vec<String> = [
        "bench".to_string(),
        "--quick".to_string(),
        format!("--steps={steps}"),
        format!("--n={n}"),
        format!("--k={k}"),
        "--scale=0.05".to_string(),
    ]
    .to_vec();
    let args = Args::parse(&argv);
    let ctx = Ctx::from_args(&args).expect("ctx");
    let mut suite = BenchSuite::new("tables");
    // Default: a representative subset (alignment, RL-breakage, data
    // ablation, size law); QADX_BENCH_ALL=1 sweeps all twelve.
    let all = std::env::var("QADX_BENCH_ALL").is_ok();
    let tables: &[usize] = if all {
        &[1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12]
    } else {
        &[1, 3, 5, 12]
    };
    for &t in tables {
        suite.run(&format!("table{t:02}_e2e"), 0, 1, || {
            if let Err(e) = exper::run_table(&ctx, t) {
                eprintln!("table{t} failed in bench: {e:#}");
            }
        });
    }
    let figs: &[usize] = if all { &[1, 2] } else { &[2] };
    for &f in figs {
        suite.run(&format!("figure{f}_e2e"), 0, 1, || {
            if let Err(e) = exper::run_figure(&ctx, f) {
                eprintln!("figure{f} failed in bench: {e:#}");
            }
        });
    }
    suite.finish();
}
