//! Micro-benchmarks of the L3 hot-path substrates: NVFP4 codec, scalar
//! mini-float rounding, sampler math, JSON parsing, batch generation.
//! `cargo bench --bench ops_bench`. CSV lands in runs/bench/ops.csv and
//! machine-readable numbers in BENCH_ops.json at the repo root.

use qadx::data::{tasks, BatchFactory, BatchShape, SourceSpec, Suite, TEXT_SUITES};
use qadx::eval::{sample_token_with, SampleCfg, SampleScratch};
use qadx::quant::baselines::{int4_fake_quant, mxfp4_fake_quant};
use qadx::quant::fp::{e2m1_round, e4m3_round};
use qadx::quant::nvfp4::Nvfp4Tensor;
use qadx::util::bench::BenchSuite;
use qadx::util::json::Json;
use qadx::util::rng::Rng;

fn main() {
    let mut suite = BenchSuite::new("ops");
    let mut rng = Rng::new(1);
    let x: Vec<f32> = (0..256 * 256).map(|_| rng.normal() as f32).collect();

    suite.run("nvfp4_quantize_256x256 (65k elems)", 2, 20, || {
        std::hint::black_box(Nvfp4Tensor::quantize(&x, 256, 256, None));
    });
    let q = Nvfp4Tensor::quantize(&x, 256, 256, None);
    suite.run("nvfp4_dequantize_256x256", 2, 20, || {
        std::hint::black_box(q.dequantize());
    });
    let mut deq_buf = vec![0f32; 256 * 256];
    suite.run("nvfp4_dequantize_into_256x256", 2, 20, || {
        q.dequantize_into(&mut deq_buf);
        std::hint::black_box(&deq_buf);
    });
    suite.run("mxfp4_fake_quant_256x256", 2, 20, || {
        std::hint::black_box(mxfp4_fake_quant(&x, 256, 256));
    });
    suite.run("int4_fake_quant_256x256", 2, 20, || {
        std::hint::black_box(int4_fake_quant(&x, 256, 256));
    });

    let vals: Vec<f32> = (0..10_000).map(|_| rng.normal() as f32 * 100.0).collect();
    suite.run("e4m3_round_10k", 2, 50, || {
        let mut acc = 0f32;
        for v in &vals {
            acc += e4m3_round(*v);
        }
        std::hint::black_box(acc);
    });
    suite.run("e2m1_round_10k", 2, 50, || {
        let mut acc = 0f32;
        for v in &vals {
            acc += e2m1_round(*v);
        }
        std::hint::black_box(acc);
    });

    // sampler math over a vocab-64 logits row (allocation-free hot path)
    let logits: Vec<f32> = (0..64).map(|_| rng.normal() as f32 * 3.0).collect();
    let cfg = SampleCfg::default();
    let mut srng = Rng::new(2);
    let mut scratch = SampleScratch::default();
    suite.run("sample_token_topp_x1000", 2, 30, || {
        for _ in 0..1000 {
            std::hint::black_box(sample_token_with(&cfg, &mut srng, &logits, &mut scratch));
        }
    });
    let greedy = SampleCfg::greedy();
    suite.run("sample_token_greedy_x1000", 2, 30, || {
        for _ in 0..1000 {
            std::hint::black_box(sample_token_with(&greedy, &mut srng, &logits, &mut scratch));
        }
    });

    // batch generation (SFT source, full text mixture)
    let shape = BatchShape { batch: 16, seq_len: 40, vision: false, grid: 4, patch: 16, vocab: 64 };
    let mut factory = BatchFactory::new(shape, vec![SourceSpec::sft(TEXT_SUITES)], 3);
    suite.run("sft_batch_generation_16x40", 2, 50, || {
        std::hint::black_box(factory.next_batch(None).unwrap());
    });

    // task generation only
    let mut trng = Rng::new(4);
    suite.run("task_generate_mixed_x100", 2, 30, || {
        for _ in 0..100 {
            let s = *trng.choice(TEXT_SUITES);
            std::hint::black_box(tasks::generate(s, &mut trng, 4, 16));
        }
    });
    let _ = Suite::Math500;

    // manifest-sized JSON parse
    let manifest_text = std::fs::read_to_string("artifacts/manifest.json").ok();
    if let Some(text) = manifest_text {
        suite.run("json_parse_manifest", 2, 20, || {
            std::hint::black_box(Json::parse(&text).unwrap());
        });
    }

    suite.finish();
}
