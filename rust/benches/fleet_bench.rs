//! Fleet-serving benchmarks: closed-loop throughput of the multi-worker
//! router at 1/2/4 workers, the cost of a mid-run worker kill (retried
//! work rides on the survivors), mixed-class overload with the priority
//! lanes off vs on, token streaming through bounded channels under a
//! lossy slow-consumer policy, and admission-control behavior under a
//! saturating burst. Entirely hermetic — a synthetic manifest on the
//! reference backend, no artifacts, no XLA; the per-token compute is the
//! same stateful prefill/step path BENCH_refgemm's ref_decode_step rows
//! measure, so fleet rows read as "that, times worker parallelism, plus
//! router overhead".
//!
//! `cargo bench --bench fleet_bench` → BENCH_fleet.json at the repo
//! root; `QADX_BENCH_SMOKE=1` clamps to one iteration for CI bit-rot
//! checks. A CLI twin of the closed/open-loop scenarios:
//! `qadx serve-bench --fleet --workers N --arrival-rate L`.

use qadx::api::{FaultPlan, FleetCfg, RequestClass, Saturated, Session, SlowConsumer, TokenSink};
use qadx::eval::SampleCfg;
use qadx::runtime::{synthetic_manifest_json, BackendKind, SynthSpec};
use qadx::util::bench::BenchSuite;

/// The bench model: refgemm-bench's shape (every GEMM crosses the
/// parallel threshold; small enough to iterate).
fn bench_spec() -> SynthSpec {
    let mut spec = SynthSpec::small("fleet-bench");
    spec.d_model = 128;
    spec.n_heads = 4;
    spec.d_ff = 256;
    spec.vocab = 512;
    spec.seq_len = 32;
    spec.batch = 4;
    spec
}

fn main() {
    let mut suite = BenchSuite::new("fleet");
    let dir = std::env::temp_dir().join(format!("qadx_fleet_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("bench tmp dir");
    std::fs::write(dir.join("manifest.json"), synthetic_manifest_json(&[bench_spec()]))
        .expect("write manifest");
    let session = Session::builder()
        .artifacts_dir(&dir)
        .runs_dir(dir.join("runs"))
        .backend(BackendKind::Reference)
        .build()
        .expect("reference session");
    let ms = session.model("fleet-bench").expect("bench model");

    let sample = SampleCfg { temperature: 0.6, top_p: 0.95, max_new: 12, seed: 7 };
    let reqs = 32usize;
    let prompts: Vec<Vec<i32>> =
        (0..reqs).map(|i| vec![2 + (i % 8) as i32, 3, 4, 5]).collect();
    // nominal decode work per iteration (rows may stop early at EOS)
    let units = (reqs * sample.max_new) as f64;

    // ---- closed-loop throughput vs worker count ----------------------
    for workers in [1usize, 2, 4] {
        let mut cfg = FleetCfg::default();
        cfg.workers = workers;
        cfg.sample = sample;
        let mut fleet = ms.fleet("fwd_nvfp4", &cfg).expect("fleet");
        suite.run_units(&format!("fleet_w{workers}_closed_req32_toks"), 1, 5, units, || {
            for p in &prompts {
                fleet.submit(p.clone()).expect("closed-loop submit");
            }
            let responses = fleet.drain().expect("drain");
            assert_eq!(responses.len(), reqs);
            std::hint::black_box(responses);
        });
        println!("  {}", fleet.stats().summary());
        fleet.shutdown();
    }

    // ---- chaos overhead: worker 1 killed mid-run ---------------------
    // A killed worker stays dead for the fleet's lifetime, so each
    // iteration builds a fresh fleet; the delta vs fleet_w2_closed is
    // the price of one death (requeue + re-prefill on the survivor)
    // plus per-iteration fleet construction.
    suite.run_units("fleet_w2_chaos_kill_req32_toks", 0, 3, units, || {
        let mut cfg = FleetCfg::default();
        cfg.workers = 2;
        cfg.sample = sample;
        cfg.fault = FaultPlan { kills: vec![(1, 2)], ..FaultPlan::default() };
        let mut fleet = ms.fleet("fwd_nvfp4", &cfg).expect("chaos fleet");
        for p in &prompts {
            fleet.submit(p.clone()).expect("chaos submit");
        }
        let responses = fleet.drain().expect("chaos drain");
        assert_eq!(responses.len(), reqs);
        assert!(responses.iter().all(|r| r.error.is_none()), "no request may degrade");
        fleet.shutdown();
        std::hint::black_box(responses);
    });

    // ---- overload: priority lanes off vs on --------------------------
    // The whole 32-request mixed burst (alternating interactive/batch)
    // overcommits a single worker many times over; total wall time is the
    // same either way (lanes reorder, they don't add work), so the row
    // delta is pure lane-arbiter overhead. The printed per-class TTFT
    // p99 is the point: the bound-4 lanes keep the interactive tail
    // bounded while batch absorbs the queueing delay.
    for (label, bound) in [("lanes_off", 0usize), ("lanes_on", 4usize)] {
        let mut cfg = FleetCfg::default();
        cfg.workers = 1;
        cfg.sample = sample;
        cfg.starvation_bound = bound;
        let mut fleet = ms.fleet("fwd_nvfp4", &cfg).expect("overload fleet");
        suite.run_units(&format!("fleet_w1_overload_{label}_req32_toks"), 0, 3, units, || {
            for (i, p) in prompts.iter().enumerate() {
                let class = if i % 2 == 0 {
                    RequestClass::Interactive
                } else {
                    RequestClass::Batch
                };
                fleet.submit_class(p.clone(), class).expect("overload submit");
            }
            let responses = fleet.drain().expect("overload drain");
            assert_eq!(responses.len(), reqs);
            std::hint::black_box(responses);
        });
        let st = fleet.stats();
        println!(
            "  {label}: int ttft p99 {:.1}ms | bat ttft p99 {:.1}ms | bypass {}",
            st.per_class.interactive.ttft_ms.percentile(99.0),
            st.per_class.batch.ttft_ms.percentile(99.0),
            st.lane_bypasses
        );
        fleet.shutdown();
    }

    // ---- streaming through bounded channels under a lossy policy -----
    // Every token rides a capacity-8 DropOldest channel into a sink; the
    // delta vs fleet_w1_closed is the relay cost, and a consumer that
    // cannot keep up costs counted drops, never worker throughput.
    {
        let mut cfg = FleetCfg::default();
        cfg.workers = 1;
        cfg.sample = sample;
        cfg.stream_buf = 8;
        cfg.slow_consumer = SlowConsumer::DropOldest;
        cfg.on_token = Some(TokenSink::new(|ev| {
            std::hint::black_box(ev.token);
        }));
        let mut fleet = ms.fleet("fwd_nvfp4", &cfg).expect("stream fleet");
        suite.run_units("fleet_w1_stream_drop_req32_toks", 0, 3, units, || {
            for p in &prompts {
                fleet.submit(p.clone()).expect("stream submit");
            }
            let responses = fleet.drain().expect("stream drain");
            assert_eq!(responses.len(), reqs);
            std::hint::black_box(responses);
        });
        println!("  {}", fleet.stats().summary());
        fleet.shutdown();
    }

    // ---- saturating burst against a bounded queue --------------------
    // 64 requests offered at once to 2 workers behind queue_cap 8:
    // admission sheds the overflow with Saturated{retry_after_ms}; the
    // row's time covers the admitted requests only (units = offered, so
    // units_per_sec reads as offered-load capacity under shedding).
    let burst = 64usize;
    let burst_prompts: Vec<Vec<i32>> =
        (0..burst).map(|i| vec![2 + (i % 8) as i32, 3, 4, 5]).collect();
    suite.run_units("fleet_w2_qcap8_burst64_offered", 0, 3, burst as f64, || {
        let mut cfg = FleetCfg::default();
        cfg.workers = 2;
        cfg.sample = sample;
        cfg.queue_cap = 8;
        let mut fleet = ms.fleet("fwd_nvfp4", &cfg).expect("burst fleet");
        let mut shed = 0usize;
        for p in &burst_prompts {
            match fleet.submit(p.clone()) {
                Ok(_) => {}
                Err(e) if e.downcast_ref::<Saturated>().is_some() => shed += 1,
                Err(e) => panic!("unexpected submit error: {e:#}"),
            }
        }
        let responses = fleet.drain().expect("burst drain");
        assert_eq!(responses.len() + shed, burst);
        println!(
            "  burst: {} completed, {} shed ({})",
            responses.len(),
            shed,
            fleet.stats().summary()
        );
        fleet.shutdown();
        std::hint::black_box(responses);
    });

    std::fs::remove_dir_all(&dir).ok();
    suite.finish();
}
