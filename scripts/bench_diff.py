#!/usr/bin/env python3
"""Compare two qadx bench JSON files (BENCH_*.json) and fail on regression.

Usage:
    bench_diff.py OLD.json NEW.json [--threshold 0.25]
    bench_diff.py FILE.json --against-baseline [--threshold 0.25]

The first form compares the "results" arrays of two files; the second
compares a single file's "results" (after) against its embedded
"baseline" array (before) — the layout `BenchSuite::finish` preserves
across regenerations. Benchmarks are matched by name on ns_per_op; any
matched benchmark slower by more than the threshold (default +25%) fails
the run with exit code 1. Unmatched names are reported but never fail.
"""

from __future__ import annotations

import argparse
import json
import sys


def load_results(path: str, key: str = "results") -> dict[str, dict]:
    with open(path) as f:
        doc = json.load(f)
    rows = doc.get(key)
    if rows is None:
        raise SystemExit(f"{path}: no {key!r} array (schema {doc.get('schema')!r})")
    out = {}
    for r in rows:
        out[r["name"]] = r
    return out


def fmt_ns(ns: float) -> str:
    if ns >= 1e6:
        return f"{ns / 1e6:.3f} ms"
    if ns >= 1e3:
        return f"{ns / 1e3:.2f} us"
    return f"{ns:.0f} ns"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("old", help="baseline JSON (or the only file with --against-baseline)")
    ap.add_argument("new", nargs="?", help="candidate JSON")
    ap.add_argument(
        "--against-baseline",
        action="store_true",
        help="compare OLD's 'results' against its own embedded 'baseline'",
    )
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.25,
        help="max allowed slowdown as a fraction (default 0.25 = +25%%)",
    )
    args = ap.parse_args()

    if args.against_baseline:
        if args.new:
            ap.error("--against-baseline takes a single file")
        old = load_results(args.old, "baseline")
        new = load_results(args.old, "results")
        old_name, new_name = "baseline", "results"
    else:
        if not args.new:
            ap.error("need OLD.json NEW.json (or --against-baseline)")
        old = load_results(args.old)
        new = load_results(args.new)
        old_name, new_name = args.old, args.new

    matched = sorted(set(old) & set(new))
    only_old = sorted(set(old) - set(new))
    only_new = sorted(set(new) - set(old))
    if not matched:
        print(f"no common benchmark names between {old_name} and {new_name}")
        return 1

    width = max(len(n) for n in matched)
    regressions = []
    print(f"{'benchmark':<{width}}  {'before':>10}  {'after':>10}  {'ratio':>7}")
    for name in matched:
        a = float(old[name]["ns_per_op"])
        b = float(new[name]["ns_per_op"])
        ratio = b / a if a > 0 else float("inf")
        flag = ""
        if ratio > 1.0 + args.threshold:
            flag = "  << REGRESSION"
            regressions.append((name, ratio))
        elif ratio < 1.0 / (1.0 + args.threshold):
            flag = "  (faster)"
        print(f"{name:<{width}}  {fmt_ns(a):>10}  {fmt_ns(b):>10}  {ratio:>6.2f}x{flag}")

    for name in only_old:
        print(f"{name:<{width}}  only in {old_name}")
    for name in only_new:
        print(f"{name:<{width}}  only in {new_name}")

    if regressions:
        print(
            f"\nFAIL: {len(regressions)} benchmark(s) regressed beyond "
            f"+{args.threshold * 100:.0f}%:"
        )
        for name, ratio in regressions:
            print(f"  {name}: {ratio:.2f}x")
        return 1
    print(f"\nOK: no regression beyond +{args.threshold * 100:.0f}% across {len(matched)} benchmarks")
    return 0


if __name__ == "__main__":
    sys.exit(main())
