#!/usr/bin/env python3
"""Splice runs/report/*.txt into EXPERIMENTS.md at the <!-- RESULTS --> marker."""

import glob
import os
import re
import sys

root = os.path.join(os.path.dirname(__file__), "..")
report_dir = os.path.join(root, sys.argv[1] if len(sys.argv) > 1 else "runs/report")
exp_path = os.path.join(root, "EXPERIMENTS.md")

blocks = []
order = [f"table{i}" for i in range(1, 13)] + ["figure1", "figure2", "qad_e2e"]
for name in order:
    path = os.path.join(report_dir, f"{name}.txt")
    if os.path.exists(path):
        with open(path) as f:
            blocks.append("```\n" + f.read().rstrip() + "\n```\n")

text = open(exp_path).read()
marker = "<!-- RESULTS -->"
if marker not in text:
    # replace previously-spliced section between markers
    text = re.sub(
        r"<!-- RESULTS-BEGIN -->.*<!-- RESULTS-END -->",
        marker,
        text,
        flags=re.S,
    )
joined = "<!-- RESULTS-BEGIN -->\n" + "\n".join(blocks) + "<!-- RESULTS-END -->"
text = text.replace(marker, joined)
open(exp_path, "w").write(text)
print(f"spliced {len(blocks)} reports into EXPERIMENTS.md")
